"""Mini YCSB session against the FB+-tree (paper §5 in miniature) plus the
serving-side view: the prefix cache under a skewed "system prompt" workload
turning the tree trie-like.

  PYTHONPATH=src:. python examples/ycsb_demo.py
"""
import numpy as np
import jax.numpy as jnp

from benchmarks.common import build_tree, make_dataset, zipf_indices
from repro.core import batch_ops as B
from repro.core.baseline import lookup_variant
from repro.serving import PrefixCache

rng = np.random.default_rng(1)

print("== YCSB-C / A on the url dataset (heavy prefix skew) ==")
keys, width = make_dataset("url", 10_000)
tree, ks = build_tree(keys, width)
idx = zipf_indices(rng, len(keys), 8192, 0.99)
qb, ql = jnp.asarray(ks.bytes[idx]), jnp.asarray(ks.lens[idx])
for var in ("base", "feature", "feature+hash"):
    f, v, st, ls = lookup_variant(tree, qb, ql, variant=var)
    print(f"  {var:13s} found={bool(f.all())} "
          f"keycmp/op={float(st.key_compares.mean()):5.2f} "
          f"lines/op={float(st.lines_touched.mean()):5.1f} "
          f"suffix_bs/op={float(st.suffix_bs.mean()):.3f}")
tree, rep = B.update_batch(tree, qb[:4096], ql[:4096],
                           jnp.arange(4096, dtype=jnp.int32))
print(f"  YCSB-A updates: batch=4096, in-batch dup ops superseded="
      f"{int(rep.conflicts)} (latch-free last-writer-wins)")

print("\n== prefix cache: shared system prompts ==")
pc = PrefixCache(n_pages=512, block_tokens=16)
system_prompts = [rng.integers(0, 30_000, size=64).astype(np.int32)
                  for _ in range(3)]
for wave in range(4):
    reqs = []
    for _ in range(8):
        sp = system_prompts[int(rng.zipf(1.5)) % 3]
        reqs.append(np.concatenate(
            [sp, rng.integers(0, 30_000, 48)]).astype(np.int32))
    hits, pages = pc.match(reqs)
    for r, h in zip(reqs, hits):
        pc.publish(r, h)
    print(f"  wave {wave}: hit blocks per request = {hits} "
          f"(prefix hit rate so far {pc.hit_rate():.2f})")
print("  tree stats:", pc.stats)
