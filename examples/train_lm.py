"""Train a small LM end to end on the synthetic pipeline, with async
checkpointing, an injected worker failure at step 60 (auto-restart from the
latest checkpoint), and the straggler watchdog.

  PYTHONPATH=src python examples/train_lm.py [--steps 200]

(CPU-scale: a reduced-config qwen3-family model; the identical loop lowers
on the production mesh — proven by the dry-run.)
"""
import argparse
import dataclasses
import json
import tempfile

import numpy as np

from repro.configs import get_config
from repro.launch.train import train_loop


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    args = ap.parse_args()
    cfg = get_config("qwen3-14b", smoke=True)
    cfg = dataclasses.replace(cfg, n_layers=4, d_model=128, d_ff=384,
                              vocab=2048, n_heads=8, n_kv_heads=4,
                              head_dim=16)
    ck = tempfile.mkdtemp(prefix="fbtree_train_ck_")
    out = train_loop(cfg, steps=args.steps, batch=args.batch, seq=args.seq,
                     ckpt_dir=ck, save_every=25, lr=2e-3,
                     inject_failure=min(60, args.steps - 2))
    ls = sorted(out["losses"].items())
    print(json.dumps({
        "first5": round(float(np.mean([l for _, l in ls[:5]])), 3),
        "last5": round(float(np.mean([l for _, l in ls[-5:]])), 3),
        "restarts": out["restarts"],
        "stragglers_flagged": len(out["stragglers"]),
        "ckpt_dir": ck,
    }, indent=1))


if __name__ == "__main__":
    main()
