"""Quickstart: the FB+-tree core API in 60 lines.

  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np
import jax.numpy as jnp

from repro.core import batch_ops as B
from repro.core import keys as K
from repro.core.baseline import lookup_variant
from repro.core.fbtree import TreeConfig, bulk_build

rng = np.random.default_rng(0)

# ---- build a tree over mixed string keys --------------------------------
keys = [f"user:{i:06d}".encode() for i in range(0, 40_000, 4)]
ks = K.make_keyset(keys, max_key_len=16)
cfg = TreeConfig.plan(max_keys=40_000, key_width=16)   # ns=64, fs=4 defaults
tree = bulk_build(cfg, ks, np.arange(len(keys), dtype=np.int32))
print(f"built: {len(keys)} keys, height={cfg.n_levels}, "
      f"leaves={int(tree.arrays.leaf_count)}")

# ---- batched point lookups ----------------------------------------------
q = K.make_keyset([b"user:000400", b"user:000401", b"user:039996"], 16)
vals, rep = B.lookup_batch(tree, q.bytes, q.lens)
print("lookup:", list(zip([bool(f) for f in rep.found],
                          [int(v) for v in vals])))

# ---- latch-free-style batched update (versions untouched) ----------------
tree, _ = B.update_batch(tree, q.bytes[:1], q.lens[:1],
                         jnp.asarray([777], jnp.int32))
print("after update:", int(B.lookup_batch(tree, q.bytes[:1], q.lens[:1])[0][0]))

# ---- bulk insert with node splits ----------------------------------------
new = K.make_keyset([f"user:{i:06d}".encode() for i in range(1, 4000, 4)], 16)
tree, repi, rounds = B.insert_batch(tree, new.bytes, new.lens,
                                    np.arange(new.n, dtype=np.int32))
print(f"inserted {new.n} keys in {rounds} bulk-split rounds "
      f"({int(repi.splits)} leaf splits)")

# ---- device build + online rebuild (DESIGN.md §5) -------------------------
tree_dev = bulk_build(cfg, ks, np.arange(len(keys), dtype=np.int32),
                      device=True)     # jit pipeline, bit-identical arrays
rm = K.make_keyset([f"user:{i:06d}".encode() for i in range(0, 20_000, 8)], 16)
tree_dev, _ = B.remove_batch(tree_dev, rm.bytes, rm.lens)
tree_dev, rep = B.rebuild(tree_dev)    # compact tombstones device-side
print(f"rebuild: {int(rep.n_live)} live keys in {int(rep.n_leaves)} leaves "
      f"({int(rep.reclaimed)} pool rows reclaimed)")

# ---- ordered range scan ---------------------------------------------------
start = K.make_keyset([b"user:000399"], 16)
kid, vals, emitted, _ = B.range_scan(tree, start.bytes, start.lens,
                                     max_items=5)
kb = np.asarray(tree.arrays.key_bytes)
print("scan from user:000399 ->",
      [bytes(kb[i]).rstrip(b"\0").decode() for i in np.asarray(kid[0][:5])])

# ---- the paper's counters: feature comparison vs binary search ------------
idx = rng.integers(0, len(keys), size=4096)
qb, ql = jnp.asarray(ks.bytes[idx]), jnp.asarray(ks.lens[idx])
for var in ("base", "feature+hash"):
    _, _, st, _ = lookup_variant(tree, qb, ql, variant=var)
    print(f"{var:13s} key_compares/op={float(st.key_compares.mean()):5.2f} "
          f"modeled_lines/op={float(st.lines_touched.mean()):5.1f}")

# ---- shard it (DESIGN.md §7): routed ops, bit-identical results -----------
from repro import shard as S

st = S.sharded_build(ks, np.arange(len(keys), dtype=np.int32), n_shards=4)
svals, srep = S.lookup_batch(st, q.bytes, q.lens)
print("sharded lookup (owner per query:", srep.owner.tolist(), ") ->",
      list(zip(srep.found.tolist(), svals.tolist())))
st, rrep = S.rebalance(st)   # skew-recovery barrier: even re-partition
print("rebalance:", rrep.counts_before, "->", rrep.counts_after)
