"""End-to-end serving driver (the paper-kind e2e example): batched requests
with skewed shared prefixes through the Engine + FB+-tree prefix cache.

  PYTHONPATH=src python examples/serve_lm.py
"""
import sys

from repro.launch.serve import main

if __name__ == "__main__":
    sys.argv = [sys.argv[0], "--arch", "yi-9b", "--requests", "24",
                "--prompt-len", "96", "--shared-prefix", "64",
                "--max-new", "12", "--max-batch", "4"] + sys.argv[1:]
    main()
