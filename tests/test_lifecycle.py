"""Versioned tree lifecycle + fault layer (DESIGN.md §8): fsck invariants
on real trees, guaranteed-detectable corruptions, atomic abortable
publishes, seeded replayable fault schedules, degraded-shard serving, and
a mini chaos sweep through the same harness CI runs at scale.
"""
import random

import numpy as np
import pytest

from repro.core import batch_ops as B
from repro.core import fsck
from repro.core import keys as K
from repro.core.faults import (CORRUPTIONS, FaultInjected, FaultPlan,
                               FaultSpec, RetryPolicy, ShardDropped,
                               corrupt_tree)
from repro.core.fbtree import TreeConfig, bulk_build
from repro.core.lifecycle import TreeVersionManager
from repro import shard as SH

W = 8
FAST = RetryPolicy(max_attempts=2, sleep=lambda s: None)


def _keyset(ints):
    return K.make_keyset([int(x).to_bytes(W, "big") for x in ints], W)


def _tree(n=160, seed=3, max_keys=1024):
    rng = np.random.default_rng(seed)
    base = np.sort(rng.choice(1 << 40, n, replace=False))
    vals = np.arange(n, dtype=np.int32)
    cfg = TreeConfig.plan(max_keys=max_keys, key_width=W)
    return bulk_build(cfg, _keyset(base), vals), base, vals, cfg


# ------------------------------------------------------------------ fsck

def test_fsck_clean_through_op_lifecycle():
    """check_tree passes on a fresh build and stays clean through inserts
    (incl. leaf splits), removes, and a device rebuild — with version
    monotonicity against the previous arrays at each step."""
    t, base, vals, cfg = _tree()
    assert fsck.check_tree(t), fsck.check_tree(t).violations
    prev = t
    new = [int(x) + 1 for x in base[:64]]        # force splits via density
    t, _, _ = B.insert_batch(t, *(_keyset(new).bytes, _keyset(new).lens),
                             np.arange(64, dtype=np.int32))
    r = fsck.check_tree(t, prev=prev)
    assert r.ok, r.violations
    prev = t
    q = _keyset([int(x) for x in base[10:40]])
    t, _ = B.remove_batch(t, q.bytes, q.lens)
    r = fsck.check_tree(t, prev=prev)
    assert r.ok, r.violations
    t2, rep = B.rebuild(t)
    r = fsck.check_tree(t2)
    assert r.ok and r.n_live == int(rep.n_live)
    # empty tree: remove everything, rebuild, still structurally valid
    live_b, live_l, *_ = B.gather_live_sorted(t)
    n_live = int(t.n_keys_live)
    t3, _ = B.remove_batch(t, np.asarray(live_b)[:n_live],
                           np.asarray(live_l)[:n_live])
    t4, _ = B.rebuild(t3)
    assert fsck.check_tree(t4).ok and t4.n_keys_live == 0


def test_fsck_version_regression_detected():
    """A published version whose leaf versions went backwards vs the
    previous arrays violates §4.2 ordering and must be flagged."""
    t, *_ = _tree(n=80)
    q = _keyset([int(x) for x in range(5)])
    t2, _, _ = B.insert_batch(t, q.bytes, q.lens,
                              np.arange(5, dtype=np.int32))
    assert fsck.check_tree(t2, prev=t).ok
    r = fsck.check_tree(t, prev=t2)      # swapped: versions regress
    assert not r.ok
    assert any("version" in v for v in r.violations), r.violations


@pytest.mark.parametrize("kind", CORRUPTIONS)
def test_fsck_detects_every_corruption(kind):
    """Each corruption in the chaos vocabulary is fsck-detectable — the
    guarantee that makes a corrupt-then-publish schedule safe to run."""
    t, *_ = _tree()
    t2, applied = corrupt_tree(t, random.Random(7), kind=kind)
    assert applied == kind
    r = fsck.check_tree(t2)
    assert not r.ok, f"{kind} went undetected"


def test_fsck_sharded_ownership():
    """check_sharded: per-shard structure plus router ownership — a key
    living in the wrong shard is a violation even if both shards are
    individually well-formed."""
    rng = np.random.default_rng(5)
    base = np.sort(rng.choice(1 << 40, 120, replace=False))
    st = SH.sharded_build(_keyset(base), np.arange(120, dtype=np.int32), 3,
                          max_keys=1024)
    assert fsck.check_sharded(st).ok
    # move shard 2's tree into shard 1's slot: shard 1 now holds keys the
    # router says belong to shard 2
    shards = list(st.shards)
    shards[1] = shards[2]
    bad = st.replace(shards=tuple(shards))
    r = fsck.check_sharded(bad)
    assert not r.ok
    assert any("route to a different shard" in v for v in r.violations)


# ------------------------------------------------------- lifecycle publish

def test_publish_success_and_abort_atomicity():
    """rebuild() as an atomic publish: success bumps the version and keeps
    the old one as rollback; an injected abort at any lifecycle step leaves
    the current version serving bit-identically."""
    t, base, vals, _ = _tree()
    q = _keyset([int(x) for x in base[:32]])
    t, _ = B.remove_batch(t, q.bytes, q.lens)    # give rebuild work
    mgr = TreeVersionManager(t)
    rep = mgr.rebuild()
    assert rep.ok and mgr.version == 1 and rep.version == 1
    assert mgr.previous is t                     # rollback version kept
    assert int(rep.aux.reclaimed) == 32

    for site in ("lifecycle.begin", "lifecycle.rebuild.gather",
                 "lifecycle.rebuild.build", "lifecycle.fsck",
                 "lifecycle.swap"):
        plan = FaultPlan((FaultSpec(site, "abort"),))
        mgr2 = TreeVersionManager(mgr.current, faults=plan)
        before = mgr2.current
        rep = mgr2.rebuild()
        assert not rep.ok and rep.reason == f"fault:{site}", (site, rep)
        assert mgr2.current is before and mgr2.version == 0, site
        v, lrep = B.lookup_batch(mgr2.current, q.bytes, q.lens)
        assert not np.asarray(lrep.found).any()  # removed keys stay gone


def test_publish_fsck_gate_blocks_corrupt_staged():
    """A staged tree corrupted between build and swap must be rejected by
    the fsck gate — the bad version is never published."""
    t, *_ = _tree()
    plan = FaultPlan((FaultSpec("lifecycle.staged", "corrupt"),),
                     seed=11)
    mgr = TreeVersionManager(t, faults=plan)
    rep = mgr.rebuild()
    assert not rep.ok and rep.reason.startswith("fsck:"), rep.reason
    assert rep.violations and mgr.version == 0 and mgr.current is t
    assert any(k.startswith("corrupt:") for _, k, _ in plan.events)
    # the serving tree itself is still clean
    assert fsck.check_tree(mgr.current).ok
    plan.disarm()
    assert mgr.rebuild().ok and mgr.version == 1


def test_fault_plan_replay_and_spec_windows():
    """Determinism contract: the same seed replays the same schedule; a
    FaultSpec nth/count window fires on exactly its visits."""
    def drive(plan):
        for i in range(6):
            try:
                plan.fire("lifecycle.step", shard=None)
            except FaultInjected:
                pass
            try:
                plan.fire("shard.dispatch.lookup", shard=i % 2)
            except FaultInjected:
                pass
        return list(plan.events)
    p = {"abort": 0.5, "drop_shard": 0.5}
    e1 = drive(FaultPlan(seed=42, p=p))
    e2 = drive(FaultPlan(seed=42, p=p))
    e3 = drive(FaultPlan(seed=43, p=p))
    assert e1 == e2 and e1 and e1 != e3
    # nth/count: skip the first visit, fire the next two, then stop —
    # tracked per (spec, shard)
    spec = FaultSpec("shard.dispatch.*", "drop_shard", nth=1, count=2)
    plan = FaultPlan((spec,))
    fired = []
    for visit in range(5):
        try:
            plan.fire("shard.dispatch.update", shard=0)
            fired.append(False)
        except ShardDropped:
            fired.append(True)
    assert fired == [False, True, True, False, False]


# --------------------------------------------------- degraded-shard serving

def _sharded(n=200, n_shards=4, seed=1):
    rng = np.random.default_rng(seed)
    base = np.sort(rng.choice(1 << 40, n, replace=False))
    vals = np.arange(n, dtype=np.int32)
    st = SH.sharded_build(_keyset(base), vals, n_shards, max_keys=1024)
    return st, base, vals


def test_transient_drop_absorbed_by_retry():
    """A one-attempt flake (nth=0, count=1) is retried and served live —
    no degraded lanes, shard stays healthy."""
    st, base, vals = _sharded()
    plan = FaultPlan((FaultSpec("shard.dispatch.lookup", "drop_shard",
                                shard=1, count=1),))
    q = _keyset([int(x) for x in base[::4]])
    v, rep = SH.lookup_batch(st, q.bytes, q.lens, faults=plan, retry=FAST)
    assert np.asarray(rep.found).all()
    assert (np.asarray(v) == vals[::4]).all()
    assert not np.asarray(rep.degraded).any()
    assert st.health.is_ok(1)
    assert ("shard.dispatch.lookup", "drop_shard", 1) in plan.events


def test_down_shard_degrades_and_recovers():
    """Retry exhaustion on a persistently down shard: lookups serve the
    last-barrier snapshot (degraded, stale-but-true), mutations flag
    exactly the down lanes failed (never partially applied), and the
    rebalance barrier is the recovery path — no committed op lost."""
    st, base, vals = _sharded()
    plan = FaultPlan((FaultSpec("shard.dispatch.*", "drop_shard",
                                shard=2),))
    q = _keyset([int(x) for x in base[::4]])
    idx = np.arange(0, 200, 4)

    v, rep = SH.lookup_batch(st, q.bytes, q.lens, faults=plan, retry=FAST)
    down = rep.owner == 2
    assert down.any() and (rep.degraded == down).all()
    assert np.asarray(rep.found).all()           # snapshot still has them
    assert (np.asarray(v) == vals[idx]).all()
    assert not st.health.is_ok(2)                # marked after exhaustion

    newv = (vals[idx] + 1000).astype(np.int32)
    st2, urep = SH.update_batch(st, q.bytes, q.lens, newv,
                                faults=plan, retry=FAST)
    assert (urep.failed == down).all()
    v2, lrep = SH.lookup_batch(st2, q.bytes, q.lens, faults=plan,
                               retry=FAST)
    assert (v2[~down] == newv[~down]).all()      # committed lanes visible
    assert (v2[down] == vals[idx][down]).all()   # stale snapshot, not junk
    assert fsck.check_sharded(st2).ok            # arrays never corrupted

    plan.heal()
    plan.disarm()
    st2.health.reset()
    st3, _ = SH.rebalance(st2)
    assert st3.health.n_unhealthy == 0
    assert fsck.check_sharded(st3).ok
    v3, rep3 = SH.lookup_batch(st3, q.bytes, q.lens)
    assert np.asarray(rep3.found).all()
    assert (np.asarray(v3)[~down] == newv[~down]).all()
    assert (np.asarray(v3)[down] == vals[idx][down]).all()


def test_manager_rebalance_recovery_barrier():
    """TreeVersionManager.rebalance over a ShardedTree: a publish that
    aborts mid-gather changes nothing; the clean retry bumps the version
    and serves identically."""
    st, base, vals = _sharded(n_shards=3)
    plan = FaultPlan((FaultSpec("lifecycle.rebalance.gather", "abort",
                                shard=1),))
    mgr = TreeVersionManager(st, faults=plan)
    rep = mgr.rebalance()
    assert not rep.ok and rep.reason == "fault:lifecycle.rebalance.gather"
    assert mgr.version == 0 and mgr.current is st
    plan.disarm()
    rep = mgr.rebalance()
    assert rep.ok and mgr.version == 1
    q = _keyset([int(x) for x in base])
    v, lrep = SH.lookup_batch(mgr.current, q.bytes, q.lens)
    assert np.asarray(lrep.found).all()
    assert (np.asarray(v) == vals).all()


# ------------------------------------------------------- input validation

def test_tree_config_validation_messages():
    with pytest.raises(ValueError, match="key_width must be >= 1"):
        TreeConfig(key_width=0)
    with pytest.raises(ValueError, match="ns must be >= 2"):
        TreeConfig(key_width=8, ns=1)
    with pytest.raises(ValueError, match="leaf_fill must be in"):
        TreeConfig(key_width=8, ns=16, leaf_fill=17)
    with pytest.raises(ValueError, match="one cap per inner level"):
        TreeConfig(key_width=8, n_levels=2, level_caps=(1, 2, 3))


def test_sharded_build_validation_messages():
    ks = _keyset([1, 2, 3])
    vals = np.arange(3, dtype=np.int32)
    with pytest.raises(ValueError, match="n_shards must be >= 1"):
        SH.sharded_build(ks, vals, 0)
    with pytest.raises(ValueError, match="sentinel keys"):
        SH.sharded_build(ks, vals, 8)
    with pytest.raises(ValueError, match="one value per"):
        SH.sharded_build(ks, vals[:2], 2)
    cfg = TreeConfig.plan(max_keys=64, key_width=16)
    with pytest.raises(ValueError, match="key_width"):
        SH.sharded_build(ks, vals, 2, cfg=cfg)


def test_range_scan_validates_max_items():
    t, base, *_ = _tree(n=40)
    q = _keyset([int(base[0])])
    with pytest.raises(ValueError, match="max_items"):
        B.range_scan(t, q.bytes, q.lens, max_items=0)
    st, base, _ = _sharded(n=40, n_shards=2)
    with pytest.raises(ValueError, match="max_items"):
        SH.range_scan(st, q.bytes, q.lens, max_items=0)


def test_sharded_tree_wiring_validation():
    """ShardedTree construction rejects mismatched router/devices/health
    sizes with actionable errors instead of asserts."""
    st, *_ = _sharded(n=40, n_shards=2)
    with pytest.raises(ValueError, match="per shard"):
        st.replace(shards=st.shards[:1])
    with pytest.raises(ValueError, match="health"):
        st.replace(health=SH.ShardHealth(3))


# ------------------------------------------------------------- mini chaos

@pytest.mark.parametrize("scenario", ("rebuild", "rebalance", "compact",
                                      "lookup"))
def test_mini_chaos_schedules(scenario):
    """A slice of the CI chaos sweep (tools/chaos_sweep.py) runs in-tree:
    every seeded schedule must end fsck-clean with no committed op lost.
    run_schedule raises on any violation."""
    from tools.chaos_sweep import run_schedule
    fired = 0
    for seed in range(2):
        for n_shards in (1, 4):
            r = run_schedule(seed, n_shards, scenario)
            fired += r["events"]
    assert fired > 0, "no faults fired — schedules proved nothing"
