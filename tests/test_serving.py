"""Serving layer: page pool, FB+-tree prefix cache, engine end-to-end."""
import numpy as np
import pytest

from repro.serving import PagePool, PrefixCache, chain_keys


def test_page_pool_alloc_free_lru():
    p = PagePool(16)
    a = p.alloc(10)
    assert a is not None and p.n_free == 6
    p.release(a[:4])
    assert set(p.evictable()) == set(a[:4].tolist())
    victims = p.lru_candidates(2)
    assert len(victims) == 2
    p.evict(victims)
    assert p.n_free == 8


def test_chain_keys_prefix_property(rng):
    toks = rng.integers(0, 1000, size=128).astype(np.int32)
    k1 = chain_keys(toks, 16)
    k2 = chain_keys(toks[:64], 16)
    assert k1[:4] == k2          # shared prefix -> identical block keys
    toks2 = toks.copy()
    toks2[40] += 1               # divergence in block 2
    k3 = chain_keys(toks2, 16)
    assert k3[:2] == k1[:2] and k3[2] != k1[2] and k3[3] != k1[3]


def test_prefix_cache_match_publish_roundtrip(rng):
    pc = PrefixCache(n_pages=256, block_tokens=16, max_keys=4096)
    sys_prompt = rng.integers(0, 500, size=64).astype(np.int32)
    r1 = np.concatenate([sys_prompt, rng.integers(0, 500, 32)]).astype(np.int32)
    r2 = np.concatenate([sys_prompt, rng.integers(0, 500, 32)]).astype(np.int32)
    hit, pages = pc.match([r1])
    assert hit == [0]
    pc.publish(r1, 0)
    hit, pages = pc.match([r2])
    assert hit == [4]            # 64 shared tokens = 4 blocks
    assert len(pages[0]) == 4
    # full re-ask of r1 hits all 6 blocks
    hit, _ = pc.match([r1])
    assert hit == [6]


def test_prefix_cache_eviction_under_pressure(rng):
    pc = PrefixCache(n_pages=8, block_tokens=8, max_keys=4096)
    for i in range(6):
        toks = rng.integers(0, 500, size=32).astype(np.int32)
        hit, _ = pc.match([toks])
        ids = pc.publish(toks, hit[0])
        assert ids is not None, "eviction should free pages"
    assert pc.stats["evicts"] > 0


def test_prefix_cache_compaction(rng):
    """Online rebuild (DESIGN.md §5): eviction churn fragments the tree;
    compact() repacks it and cached lookups still resolve."""
    pc = PrefixCache(n_pages=64, block_tokens=8, max_keys=4096,
                     compact_factor=0)   # manual compaction only
    for _ in range(10):                  # churn: publish + force evictions
        toks = rng.integers(500, 1000, size=64).astype(np.int32)
        hit, _ = pc.match([toks])
        pc.publish(toks, hit[0])
    assert pc.stats["evicts"] > 0
    kept = rng.integers(0, 500, size=64).astype(np.int32)
    assert pc.publish(kept, 0) is not None
    leaves_before = int(pc.tree.arrays.leaf_count)
    live_before = pc.tree.n_keys_live
    rep = pc.compact()                   # -> lifecycle PublishReport
    assert rep.ok and rep.version == 1
    assert pc.stats["rebuilds"] == 1
    assert int(rep.aux.n_live) == live_before
    assert int(rep.aux.reclaimed) > 0    # tombstoned digests dropped
    assert int(pc.tree.arrays.leaf_count) <= leaves_before
    hit, pages = pc.match([kept])        # cached pages survive the barrier
    assert hit == [len(kept) // 8]
    assert len(pages[0]) == len(kept) // 8
    assert pc.frag_factor >= 1.0


def test_prefix_cache_pool_headroom_compaction(rng):
    """Steady churn appends a new pool row per distinct digest while evicted
    digests only tombstone; the publish() headroom guard must compact
    (reclaiming those rows) instead of letting insert_batch overflow the
    pool and raise (DESIGN.md §5)."""
    pc = PrefixCache(n_pages=32, block_tokens=8, max_keys=256,
                     compact_factor=0)   # frag trigger off: isolate the guard
    for _ in range(40):      # 40 waves x 8 blocks = 320 distinct digests
        toks = rng.integers(0, 10**6, size=64).astype(np.int32)
        hit, _ = pc.match([toks])
        assert pc.publish(toks, hit[0]) is not None
    assert pc.stats["rebuilds"] >= 1
    assert int(pc.tree.arrays.key_count) <= 256


def test_prefix_cache_compact_abort_keeps_serving(rng):
    """Crash-safety regression (DESIGN.md §8): compact() used to rebuild
    in place — an abort mid-rebuild could leave the cache serving a
    half-built tree. Now it is an atomic publish: the fault fails the
    barrier, the old version keeps serving bit-identically, and a later
    fault-free compact succeeds."""
    from repro.core.faults import FaultPlan, FaultSpec
    plan = FaultPlan((FaultSpec("lifecycle.rebuild.build", "abort"),))
    plan.disarm()
    pc = PrefixCache(n_pages=64, block_tokens=8, max_keys=4096,
                     compact_factor=0, faults=plan)
    for _ in range(8):                   # churn to give compact real work
        toks = rng.integers(500, 1000, size=64).astype(np.int32)
        hit, _ = pc.match([toks])
        pc.publish(toks, hit[0])
    kept = rng.integers(0, 500, size=64).astype(np.int32)
    assert pc.publish(kept, 0) is not None
    ref_hits, ref_pages = pc.match([kept])
    live = pc.tree.n_keys_live
    kc = int(pc.tree.arrays.key_count)

    plan.arm()
    rep = pc.compact()                   # the barrier dies mid-build
    assert not rep.ok and rep.reason == "fault:lifecycle.rebuild.build"
    assert pc.lifecycle.version == 0     # nothing published
    assert pc.stats["rebuilds"] == 0
    # serving is bit-identical to before the failed barrier
    assert pc.tree.n_keys_live == live
    assert int(pc.tree.arrays.key_count) == kc
    assert pc.match([kept]) == (ref_hits, ref_pages)

    plan.disarm()
    rep = pc.compact()                   # recovery: clean publish
    assert rep.ok and pc.lifecycle.version == 1
    assert int(pc.tree.arrays.key_count) < kc     # tombstones reclaimed
    assert pc.match([kept]) == (ref_hits, ref_pages)


def test_engine_end_to_end_prefix_reuse(rng):
    import jax
    from repro.configs import get_config
    from repro.models import lm
    from repro.serving.engine import Engine, Request, ServeConfig
    cfg = get_config("yi-9b", smoke=True)
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    scfg = ServeConfig(max_batch=2, s_max=96, block_tokens=8, n_pages=128,
                       max_new_tokens=4)
    eng = Engine(cfg, params, scfg)
    shared = rng.integers(0, cfg.vocab, size=32).astype(np.int32)
    reqs = [np.concatenate([shared, rng.integers(0, cfg.vocab, 8)])
            .astype(np.int32) for _ in range(6)]
    done = eng.run(reqs)
    assert all(r.done for r in done)
    assert all(len(r.out) >= 4 for r in done)
    assert eng.prefix.hit_rate() > 0.2   # later requests reuse shared blocks
    # determinism: same prompt twice -> same continuation
    eng2 = Engine(cfg, params, scfg)
    d1 = eng2.run([reqs[0]])[0].out
    eng3 = Engine(cfg, params, scfg)
    d2 = eng3.run([reqs[0]])[0].out
    assert d1 == d2
