"""§4 synchronization protocol: linearizability-style invariants under
hypothesis-driven schedules (latch-free update, link-technique splits)."""
from hypothesis import HealthCheck, given, settings, strategies as st

from repro.core.protocol import Sim, check_invariants, run_schedule

op_st = st.tuples(st.sampled_from(["lookup", "update", "insert", "remove"]),
                  st.integers(0, 30))


@settings(deadline=None, max_examples=60,
          suppress_health_check=list(HealthCheck))
@given(ops=st.lists(op_st, min_size=2, max_size=24),
       schedule=st.lists(st.integers(0, 7), min_size=0, max_size=400),
       init=st.sets(st.integers(0, 30), max_size=12),
       seed=st.integers(0, 2**32 - 1))
def test_interleaved_ops_linearize(ops, schedule, init, seed):
    # the seed is part of the hypothesis example: once the explicit
    # schedule runs dry, the fallback scheduling draws from Sim's own
    # seeded RNG, so a shrunk failure replays bit-for-bit
    sim = Sim(keys=init, seed=seed)
    gens = []
    for i, (kind, key) in enumerate(ops):
        if kind == "lookup":
            gens.append(sim.lookup(key))
        elif kind == "update":
            gens.append(sim.update(key, ("u", i)))
        elif kind == "insert":
            gens.append(sim.insert(key, ("i", i)))
        else:
            gens.append(sim.remove(key))
    run_schedule(sim, gens, iter(schedule))
    check_invariants(sim)


@settings(deadline=None, max_examples=20,
          suppress_health_check=list(HealthCheck))
@given(st.integers(0, 2**32 - 1))
def test_update_contention_single_key(seed):
    """Many updates on ONE key (the paper's high-contention case): exactly
    one final value, and it must be some committed update's value."""
    sim = Sim(keys=[5], seed=seed)
    gens = [sim.update(5, ("u", i)) for i in range(8)]
    # no explicit schedule: every step draws from the seeded sim.rng
    run_schedule(sim, gens, None, rng=seed)
    check_invariants(sim)
    assert sim.contents()[5][0] in ("u", "init", "i")


def test_split_during_update_chases_sibling():
    """Deterministic schedule: update stalls, split migrates the kv, update
    must chase the sibling and still commit (paper Fig. 10 bottom)."""
    sim = Sim(keys=range(0, 8))          # leaf NS=8 -> full
    upd = sim.update(7, ("u", 0))
    ins = sim.insert(100, ("i", 0))      # forces split of the full leaf
    # advance update to just before its CAS (3 yields: locate, snap, find)
    for _ in range(3):
        next(upd)
    # run insert to completion (performs the split, moves key 7)
    for _ in ins:
        pass
    # resume update: must discover migration and succeed on the sibling
    for _ in upd:
        pass
    check_invariants(sim)
    assert sim.contents()[7] == ("u", 0)
