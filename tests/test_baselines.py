"""Factor-analysis baselines: every variant must return identical results;
the modeled hardware counters must reproduce the paper's orderings."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import keys as K
from repro.core.baseline import VARIANTS, lookup_variant
from repro.core.fbtree import TreeConfig, bulk_build


@pytest.fixture(scope="module")
def tree_and_keys():
    rng = np.random.default_rng(42)
    # skewed string keys: shared prefixes (zipf-ish families)
    fams = [b"com.example.", b"org.acme.", b"io.x.", b"net.service.deep."]
    keys = list({fams[int(rng.zipf(1.4)) % 4] + bytes(rng.integers(97, 123, size=8, dtype=np.uint8)) for _ in range(3000)})
    ks = K.make_keyset(keys, 32)
    cfg = TreeConfig.plan(max_keys=2 * len(keys), key_width=32)
    t = bulk_build(cfg, ks, np.arange(len(keys), dtype=np.int32))
    return t, ks, keys


def test_variants_agree(tree_and_keys):
    t, ks, keys = tree_and_keys
    qb, ql = jnp.asarray(ks.bytes[:512]), jnp.asarray(ks.lens[:512])
    outs = {}
    for var in VARIANTS:
        found, val, st, ls = lookup_variant(t, qb, ql, variant=var)
        assert bool(found.all()), var
        outs[var] = np.asarray(val)
    for var in VARIANTS[1:]:
        assert (outs[var] == outs[VARIANTS[0]]).all(), var


def _dense_keys(n=3000):
    """ycsb-style keys: long shared plen, then dense digits — the paper's
    'dense' regime where feature comparison fully resolves branches."""
    rng = np.random.default_rng(5)
    keys = list({f"user{int(x):016d}".encode()
                 for x in rng.integers(0, 10**15, size=2 * n)})[:n]
    return keys


def test_feature_reduces_key_compares_and_lines(tree_and_keys):
    """Fig 12a ordering on dense keys: feature comparison slashes full-key
    compares; the hashtag leaf drops further lines."""
    keys = _dense_keys()
    ks = K.make_keyset(keys, 24)
    cfg = TreeConfig.plan(max_keys=2 * len(keys), key_width=24)
    t = bulk_build(cfg, ks, np.arange(len(keys), dtype=np.int32))
    qb, ql = jnp.asarray(ks.bytes[:1024]), jnp.asarray(ks.lens[:1024])
    stats = {}
    for var in VARIANTS:
        _, _, st, ls = lookup_variant(t, qb, ql, variant=var)
        stats[var] = (float(st.key_compares.mean()),
                      float(st.lines_touched.mean()))
    assert stats["feature"][0] < 0.3 * stats["base"][0]
    assert stats["feature+hash"][1] < stats["feature"][1]
    assert stats["feature"][1] < stats["base"][1]


def test_suffix_fallback_rate_drops_with_fs(tree_and_keys):
    """Fig 13b analogue: suffix binary searches decrease as fs grows (dense
    keys; url-like family prefixes keep a floor — the paper's sparse case,
    checked for monotonicity only)."""
    for keyset, need_big_drop in ((_dense_keys(), True),
                                  (tree_and_keys[2], False)):
        ks = K.make_keyset(keyset, 32)
        rates = []
        for fs in (1, 2, 4, 8):
            cfg = TreeConfig.plan(max_keys=2 * len(keyset), key_width=32,
                                  fs=fs)
            t = bulk_build(cfg, ks, np.arange(len(keyset), dtype=np.int32))
            qb = jnp.asarray(ks.bytes[:1024])
            ql = jnp.asarray(ks.lens[:1024])
            _, _, st, _ = lookup_variant(t, qb, ql, variant="feature+hash")
            rates.append(float(st.suffix_bs.mean()))
        assert rates[0] >= rates[1] >= rates[3] - 1e-9
        if need_big_drop:
            assert rates[3] < 0.5 * max(rates[0], 1e-9) or rates[0] == 0
