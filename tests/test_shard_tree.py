"""Sharded-tree subsystem (DESIGN.md §7): routing, cross-shard op parity,
spill-to-next-shard scans, rebalance, and the routed-op mask hooks.

The central contract is *parity*: every batch op on a ``ShardedTree`` is
bit-identical — values, found-ness, emitted counts, resolved key bytes —
to the same op on ONE unsharded tree over the same keys, for shard counts
{1, 2, 4}, across engine backends, on ordered and dirtied leaves alike.
All shard counts and the unsharded reference share one ``TreeConfig`` so
the whole matrix reuses one jit specialization per op.
"""
import subprocess
import sys

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import HealthCheck, given, settings, strategies as st

from repro import shard as S
from repro.core import batch_ops as B
from repro.core import keys as K
from repro.core.fbtree import EMPTY, TreeConfig, bulk_build, sharded_partition
from repro.core.traverse import TraversalEngine

from benchmarks.common import make_dataset

SHARD_COUNTS = (1, 2, 4)
# jnp reference engine + the fused whole-descent/whole-scan kernel — the
# two extremes of the backend matrix (pallas rides the same level-backend
# path as jnp through the engine)
ENGINES = (TraversalEngine("jnp"),
           TraversalEngine("fused", layout="stacked"))


def _dataset_tree(ds_name, n_keys, seed, dirty=False):
    """(unsharded reference tree, KeySet, shared cfg, vals). ``dirty``
    in-place-inserts perturbed keys so some leaves drop ``leaf_ordered``."""
    keys, width = make_dataset(ds_name, n_keys, seed=seed)
    ks = K.make_keyset(keys, width)
    cfg = TreeConfig.plan(max_keys=3 * n_keys, key_width=width)
    vals = np.arange(len(keys), dtype=np.int32)
    return ks, cfg, vals


def _build_both(ks, cfg, vals, n_shards, dirty_ks=None):
    """Unsharded reference + ShardedTree from the same keys (+ optional
    dirtying insert applied to both)."""
    ref = bulk_build(cfg, ks, vals)
    stree = S.sharded_build(ks, vals, n_shards, cfg=cfg)
    if dirty_ks is not None:
        dv = np.arange(dirty_ks.n, dtype=np.int32) + (1 << 20)
        ref, _, _ = B.insert_batch(ref, dirty_ks.bytes, dirty_ks.lens, dv)
        stree, _, _ = S.insert_batch(stree, dirty_ks.bytes, dirty_ks.lens,
                                     dv)
    return ref, stree


def _perturbed_queries(ks, rng, n, miss_frac=0.33):
    """Query batch mixing existing keys and perturbed (mostly-miss) keys."""
    picks = rng.integers(0, ks.n, size=n)
    qb = ks.bytes[picks].copy()
    ql = ks.lens[picks].copy()
    flip = rng.random(n) < miss_frac
    qb[flip, -1] ^= 0xA5
    return qb, ql


def _assert_scan_parity(ref, stree, qb, ql, max_items, engine, ctx):
    k_ref, v_ref, em_ref, _ = B.range_scan(ref, qb, ql,
                                           max_items=max_items,
                                           engine=engine)
    gk, v_sh, em_sh, _, failed = S.range_scan(stree, qb, ql,
                                              max_items=max_items,
                                              engine=engine)
    assert not failed.any(), ctx        # fault-free scans never degrade
    assert (np.asarray(em_ref) == em_sh).all(), ctx
    assert (np.asarray(v_ref) == v_sh).all(), ctx
    # key ids are pool-local — parity is on the resolved key bytes
    sb, sl = stree.key_rows(gk)
    k_ref = np.asarray(k_ref)
    rb = np.asarray(ref.arrays.key_bytes)[np.maximum(k_ref, 0)]
    rb = np.where((k_ref >= 0)[..., None], rb, 0)
    rl = np.where(k_ref >= 0, np.asarray(ref.arrays.key_lens)[
        np.maximum(k_ref, 0)], 0)
    assert (sb == rb).all() and (sl == rl).all(), ctx
    # EMPTY past emitted on both sides
    past = np.arange(max_items)[None, :] >= em_sh[:, None]
    assert (gk[past] == EMPTY).all(), ctx


@settings(deadline=None, max_examples=4,
          suppress_health_check=list(HealthCheck))
@given(st.sampled_from(("rand-int", "ycsb", "url")), st.booleans(),
       st.integers(0, 2**31 - 1))
def test_shard_op_parity(ds_name, dirty, seed):
    """The §7 parity property: lookup / update / insert / remove /
    range_scan on a ShardedTree ≡ the unsharded op, for shard counts
    {1, 2, 4} × engines × ordered/dirty leaves."""
    n_keys = 300
    ks, cfg, vals = _dataset_tree(ds_name, n_keys, seed % 1000)
    rng = np.random.default_rng(seed)
    dirty_ks = None
    if dirty:
        # perturb existing keys: in-place fit inserts that clear
        # leaf_ordered mid-range (same recipe as the scan suite)
        db, dl = _perturbed_queries(ks, rng, 40, miss_frac=1.0)
        uniq = {(bytes(db[i].tobytes()), int(dl[i])) for i in range(40)}
        uniq -= {(bytes(ks.bytes[i].tobytes()), int(ks.lens[i]))
                 for i in range(ks.n)}
        db = np.stack([np.frombuffer(b, np.uint8) for b, _ in uniq])
        dl = np.asarray([l for _, l in uniq], np.int32)
        dirty_ks = K.KeySet(db, dl)

    qb, ql = _perturbed_queries(ks, rng, 48)
    upd_vals = rng.integers(0, 1 << 20, size=48).astype(np.int32)

    for n_shards in SHARD_COUNTS:
        ref, stree = _build_both(ks, cfg, vals, n_shards, dirty_ks)
        if dirty:
            n_dirty = sum(
                int((~np.asarray(t.arrays.leaf_ordered)
                     [:int(t.arrays.leaf_count)]).sum())
                for t in stree.shards)
            assert n_dirty > 0, "dirtying produced no unordered leaves"
        for eng in ENGINES:
            ctx = (ds_name, n_shards, eng.backend, dirty)
            # ---- lookup
            v_ref, rep_ref = B.lookup_batch(ref, qb, ql, engine=eng)
            v_sh, rep_sh = S.lookup_batch(stree, qb, ql, engine=eng)
            f_ref = np.asarray(rep_ref.found)
            assert (f_ref == rep_sh.found).all(), ctx
            assert (np.asarray(v_ref)[f_ref] == v_sh[f_ref]).all(), ctx
            # ---- range scan (covers the spill-to-next-shard path: some
            # queries start near shard boundaries by construction)
            _assert_scan_parity(ref, stree, qb[:16], ql[:16], 48, eng, ctx)

        # ---- mutations (jnp engine; backends share the descent parity
        # suite, and mutation state is engine-independent)
        eng = ENGINES[0]
        ctx = (ds_name, n_shards, "mutations", dirty)
        r2, rep_r = B.update_batch(ref, qb, ql, upd_vals, engine=eng)
        s2, rep_s = S.update_batch(stree, qb, ql, upd_vals, engine=eng)
        assert (np.asarray(rep_r.found) == rep_s.found).all(), ctx
        assert int(rep_r.conflicts) == int(rep_s.conflicts), ctx

        r3, rep_r, _ = B.insert_batch(r2, qb, ql, upd_vals, engine=eng)
        s3, rep_s, _ = S.insert_batch(s2, qb, ql, upd_vals, engine=eng)
        assert (np.asarray(rep_r.found) == rep_s.found).all(), ctx
        assert r3.n_keys_live == s3.n_keys_live, ctx

        r4, rep_r = B.remove_batch(r3, qb[::2], ql[::2], engine=eng)
        s4, rep_s = S.remove_batch(s3, qb[::2], ql[::2], engine=eng)
        assert (np.asarray(rep_r.found) == rep_s.found).all(), ctx
        assert r4.n_keys_live == s4.n_keys_live, ctx

        # post-mutation read-back: every surviving write is identical
        v_ref, rep_ref = B.lookup_batch(r4, qb, ql, engine=eng)
        v_sh, rep_sh = S.lookup_batch(s4, qb, ql, engine=eng)
        f_ref = np.asarray(rep_ref.found)
        assert (f_ref == rep_sh.found).all(), ctx
        assert (np.asarray(v_ref)[f_ref] == v_sh[f_ref]).all(), ctx
        _assert_scan_parity(r4, s4, qb[:8], ql[:8], 64, eng, ctx)


def test_router_boundaries():
    """Router contract: shard s owns [split[s], split[s+1]); shard 0's
    range is open below; equal-to-split routes right; the length
    tie-break matches the byte-compare order."""
    splits = [b"b", b"dd", b"f"]
    router = S.make_router([(np.frombuffer(k.ljust(4, b"\x00"), np.uint8),
                             len(k)) for k in splits])
    qs = [b"a", b"b", b"c", b"d", b"dd", b"dd\x01", b"ddd", b"e", b"f", b"z"]
    ks = K.make_keyset(qs, 4)
    owner = np.asarray(S.route(router, jnp.asarray(ks.bytes),
                               jnp.asarray(ks.lens)))
    #      a  b  c  d  dd dd. ddd e  f  z
    want = [0, 0, 0, 0, 1, 1, 1, 1, 2, 2]
    assert owner.tolist() == want, owner.tolist()


def test_sharded_partition_invariants():
    """Balanced contiguous runs, ascending split keys, sizes differ by at
    most one, and the runs concatenate back to the sorted key set."""
    rng = np.random.default_rng(11)
    keys = sorted({int(x) for x in rng.integers(0, 2**62, size=200)})
    ks = K.make_keyset(keys, 8)
    vals = np.arange(len(keys), dtype=np.int32)
    parts, split_keys = sharded_partition(ks, vals, 3)
    sizes = [p.n for p, _ in parts]
    assert sum(sizes) == len(keys) and max(sizes) - min(sizes) <= 1
    glued = np.concatenate([p.bytes for p, _ in parts])
    order = K.lex_sort_indices(ks)
    assert (glued == ks.bytes[order]).all()
    for (p, pv), (mb, ml) in zip(parts, split_keys):
        assert (p.bytes[0] == mb).all() and int(p.lens[0]) == ml
    # presorted=True on already-sorted input (the rebalance path) is
    # identical to the sorting path
    sks = K.KeySet(ks.bytes[order], ks.lens[order])
    parts2, split2 = sharded_partition(sks, vals[order], 3, presorted=True)
    for (p, pv), (p2, pv2) in zip(parts, parts2):
        assert (p.bytes == p2.bytes).all() and (pv == pv2).all()
    with pytest.raises(ValueError, match="at least one key per shard"):
        sharded_partition(K.make_keyset(keys[:2], 8), vals[:2], 3)


def test_routed_mask_hook():
    """The batch_ops routed-op hook: mask=False lanes are no-ops for
    update / remove / insert (no write, no pool append, not pending)."""
    rng = np.random.default_rng(5)
    keys = sorted({int(x) for x in rng.integers(0, 2**62, size=100)})
    ks = K.make_keyset(keys, 8)
    cfg = TreeConfig.plan(max_keys=400, key_width=8)
    t = bulk_build(cfg, ks, np.arange(len(keys), dtype=np.int32))
    qb, ql = ks.bytes[:8], ks.lens[:8]
    mask = jnp.asarray([True, False] * 4)

    t2, rep = B.update_batch(t, qb, ql, jnp.full((8,), 999, jnp.int32),
                             mask=mask)
    v, _ = B.lookup_batch(t2, qb, ql)
    assert (np.asarray(v) == np.where(np.asarray(mask), 999,
                                      np.arange(8))).all()
    assert np.asarray(rep.found).all()      # found reported mask-blind

    t3, rep = B.remove_batch(t2, qb, ql, mask=mask)
    _, rep2 = B.lookup_batch(t3, qb, ql)
    assert (np.asarray(rep2.found) == ~np.asarray(mask)).all()

    # insert: masked-out NEW keys must not append to the pool
    nks = K.make_keyset([int(x) + 1 for x in keys[:4]], 8)
    kc0 = int(t3.arrays.key_count)
    t4, rep, _ = B.insert_batch(t3, nks.bytes, nks.lens,
                                np.arange(4, dtype=np.int32),
                                mask=jnp.asarray([True, True, False, False]))
    assert int(t4.arrays.key_count) == kc0 + 2
    _, rep3 = B.lookup_batch(t4, nks.bytes, nks.lens)
    assert np.asarray(rep3.found).tolist() == [True, True, False, False]


def test_rebalance_recovers_skew():
    """Skewed ingest concentrates keys in one shard; rebalance re-splits
    evenly, preserves every (key, value), and refreshes the router."""
    rng = np.random.default_rng(9)
    base = sorted({int(x) for x in rng.integers(0, 2**40, size=160)})
    ks = K.make_keyset(base, 8)
    vals = np.arange(len(base), dtype=np.int32)
    st = S.sharded_build(ks, vals, 4, max_keys=2000)
    # skew: every new key routes to the LAST shard (beyond current max)
    hot = [int(x) + 2**50 for x in range(200)]
    hks = K.make_keyset(hot, 8)
    st2, _, _ = S.insert_batch(st, hks.bytes, hks.lens,
                               np.arange(200, dtype=np.int32) + 1000)
    counts = [int(t.n_keys_live) for t in st2.shards]
    assert counts[-1] >= 200, counts
    st3, rep = S.rebalance(st2)
    assert rep.n_live == st2.n_keys_live == st3.n_keys_live
    after = list(rep.counts_after)
    assert max(after) - min(after) <= 1, after
    # router moved: splits now cover the hot range
    assert after != list(rep.counts_before)
    # every key still reads back with its value
    allb = np.concatenate([ks.bytes, hks.bytes])
    alll = np.concatenate([ks.lens, hks.lens])
    v, rep2 = S.lookup_batch(st3, allb, alll)
    assert rep2.found.all()
    want = np.concatenate([vals, np.arange(200, dtype=np.int32) + 1000])
    assert (v == want).all()
    # n_shards == 1 degenerates to rebuild: same live set, one shard
    st1 = S.sharded_build(ks, vals, 1, max_keys=2000)
    st1b, rep1 = S.rebalance(st1)
    ref, _ = B.rebuild(st1.shards[0])
    assert st1b.shards[0].n_keys_live == ref.n_keys_live


def test_scan_spills_across_shards():
    """A scan starting in the last leaves of shard s must continue into
    shard s+1 (and further) until max_items — the continuation the leaf
    chain would have provided unsharded."""
    keys = list(range(0, 1200, 3))
    ks = K.make_keyset(keys, 8)
    vals = np.arange(len(keys), dtype=np.int32)
    cfg = TreeConfig.plan(max_keys=1600, key_width=8)
    ref = bulk_build(cfg, ks, vals)
    st = S.sharded_build(ks, vals, 4, cfg=cfg)
    # start just below each shard boundary → must cross into later shards
    starts = [int(K.decode_uint64(np.asarray(sb[:8], np.uint8)[None])[0])
              for sb in np.asarray(st.router.split_bytes)[1:]]
    starts = [s - 2 for s in starts] + [0]
    sks = K.make_keyset(starts, 8)
    M = 150   # > one shard's tail, forces multi-shard merge
    _assert_scan_parity(ref, st, sks.bytes, sks.lens, M,
                        TraversalEngine("jnp"), "boundary spill")
    # drain-to-end: max_items beyond the whole key set stops at the last key
    gk, v, em, _, _ = S.range_scan(st, sks.bytes[-1:], sks.lens[-1:],
                                   max_items=512)
    assert int(em[0]) == len(keys)


def test_scan_spill_into_unhealthy_shard():
    """Degraded-serving contract (DESIGN.md §8): a scan lane that must
    continue into an unhealthy shard is flagged ``failed`` with a
    prefix-correct emission — never a silently truncated 'complete'
    result and never a stale-snapshot splice (contiguity would lie)."""
    from repro.core.faults import FaultPlan, FaultSpec, RetryPolicy
    keys = list(range(0, 1200, 3))
    ks = K.make_keyset(keys, 8)
    vals = np.arange(len(keys), dtype=np.int32)
    cfg = TreeConfig.plan(max_keys=1600, key_width=8)
    st = S.sharded_build(ks, vals, 4, cfg=cfg)
    fast = RetryPolicy(max_attempts=2, sleep=lambda s: None)
    plan = FaultPlan((FaultSpec("shard.dispatch.range_scan",
                                "drop_shard", shard=1),))
    # lane 0 starts just below the shard-1 boundary (must spill into the
    # dropped shard); lane 1 lives entirely inside healthy shard 3
    b1 = np.asarray(st.router.split_bytes)[1]
    # 7 below the boundary: two shard-0 keys (stride 3) precede the spill
    start0 = int(K.decode_uint64(b1[None, :8].astype(np.uint8))[0]) - 7
    start3 = int(K.decode_uint64(np.asarray(
        st.router.split_bytes)[3][None, :8].astype(np.uint8))[0])
    sks = K.make_keyset([start0, start3], 8)
    M = 40  # > shard-0 tail for lane 0, < shard-3 size for lane 1
    gk, v, em, _, failed = S.range_scan(st, sks.bytes, sks.lens,
                                        max_items=M, faults=plan,
                                        retry=fast)
    assert failed.tolist() == [True, False]
    assert st.health.is_ok(1) is False     # retries exhausted -> marked
    # lane 0's emissions are exactly the healthy prefix (shard 0's tail),
    # bit-identical to the fault-free scan up to that point
    gk2, v2, em2, _, f2 = S.range_scan(
        S.sharded_build(ks, vals, 4, cfg=cfg), sks.bytes, sks.lens,
        max_items=M)
    assert not f2.any() and int(em2[0]) == M
    n0 = int(em[0])
    assert 0 < n0 < M, n0                  # partial, and visibly so
    assert (gk[0, :n0] == gk2[0, :n0]).all()
    assert (v[0, :n0] == v2[0, :n0]).all()
    assert (gk[0, n0:] == EMPTY).all()     # no phantom tail
    # the healthy lane is untouched by the other lane's failure
    assert int(em[1]) == int(em2[1])
    assert (gk[1] == gk2[1]).all() and (v[1] == v2[1]).all()


def test_scan_clustered_owners():
    """Regression: a batch whose owners skip middle shards (e.g. {0, 3})
    must still scan the later owners — the shard loop may find no active
    lane at shard 1/2 (lane 0 already filled) but cannot stop there."""
    keys = list(range(0, 1600, 4))
    ks = K.make_keyset(keys, 8)
    vals = np.arange(len(keys), dtype=np.int32)
    cfg = TreeConfig.plan(max_keys=2000, key_width=8)
    ref = bulk_build(cfg, ks, vals)
    st = S.sharded_build(ks, vals, 4, cfg=cfg)
    # lane 0 starts (and fills) in shard 0; lane 1 starts in the LAST shard
    last_min = np.asarray(st.router.split_bytes)[-1]
    qb = np.stack([ks.bytes[0], last_min])
    ql = np.asarray([int(ks.lens[0]), 8], np.int32)
    M = 20  # small: lane 0 fills inside shard 0
    _assert_scan_parity(ref, st, qb, ql, M, TraversalEngine("jnp"),
                        "clustered owners")


def test_shard_public_surface():
    """__all__ exports exist and the deep modules aren't required."""
    import repro.serving as serving
    import repro.shard as shard
    for name in shard.__all__:
        assert hasattr(shard, name), name
    for name in serving.__all__:
        assert hasattr(serving, name), name
    assert "ShardedTree" in shard.__all__
    assert "PrefixCache" in serving.__all__


def test_sharded_prefix_cache_roundtrip(rng):
    """The optional sharded cache mode (DESIGN.md §7): match/publish/evict
    /compact through the shard layer, hits identical to the unsharded
    cache."""
    from repro.serving import PrefixCache
    pc1 = PrefixCache(n_pages=256, block_tokens=16, max_keys=4096)
    pc4 = PrefixCache(n_pages=256, block_tokens=16, max_keys=4096,
                      n_shards=4)
    sysp = rng.integers(0, 500, size=64).astype(np.int32)
    r1 = np.concatenate([sysp, rng.integers(0, 500, 32)]).astype(np.int32)
    r2 = np.concatenate([sysp, rng.integers(0, 500, 32)]).astype(np.int32)
    for pc in (pc1, pc4):
        hit, _ = pc.match([r1])
        assert hit == [0]
        pc.publish(r1, 0)
        hit, pages = pc.match([r2])
        assert hit == [4] and len(pages[0]) == 4
    assert pc4.tree.n_shards == 4
    # a small live set is NOT fragmentation: one leaf per shard is the
    # sharded floor, so the evict-time trigger must not thrash compacts
    assert pc4.frag_factor < pc4.compact_factor
    rep = pc4.compact()          # cross-shard barrier; pages survive
    assert pc4.stats["rebuilds"] == 1
    assert pc4.frag_factor < pc4.compact_factor   # ... and stays cleared
    hit, _ = pc4.match([r1])
    assert hit == [6]


FORCED_MESH_SNIPPET = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import numpy as np
import jax
from repro import shard as S
from repro.core import batch_ops as B, keys as K
from repro.core.fbtree import TreeConfig, bulk_build
assert len(jax.devices()) == 4
rng = np.random.default_rng(1)
keys = sorted({int(x) for x in rng.integers(0, 2**62, size=300)})[:256]
ks = K.make_keyset(keys, 8)
vals = np.arange(len(keys), dtype=np.int32)
cfg = TreeConfig.plan(max_keys=1024, key_width=8)
st = S.sharded_build(ks, vals, 4, cfg=cfg, device=True)
devs = {list(t.arrays.key_count.devices())[0] for t in st.shards}
assert len(devs) == 4, devs
ref = bulk_build(cfg, ks, vals)
v_ref, _ = B.lookup_batch(ref, ks.bytes, ks.lens)
v_sh, rep = S.lookup_batch(st, ks.bytes, ks.lens)
assert rep.found.all() and (np.asarray(v_ref) == v_sh).all()
gk, v, em, _, _ = S.range_scan(st, ks.bytes[:4], ks.lens[:4], max_items=64)
kr, vr, er, _ = B.range_scan(ref, ks.bytes[:4], ks.lens[:4], max_items=64)
assert (np.asarray(er) == em).all() and (np.asarray(vr) == v).all()
print("OK")
"""


def test_forced_multi_device_mesh():
    """End-to-end on a real 4-device mesh (forced CPU devices — the env
    must be set before jax imports, hence the subprocess): one shard per
    device, ops parity across devices."""
    import os
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    env["PYTHONPATH"] = "src" + (
        os.pathsep + env["PYTHONPATH"] if "PYTHONPATH" in env else "")
    out = subprocess.run([sys.executable, "-c", FORCED_MESH_SNIPPET],
                         capture_output=True, text=True, timeout=600,
                         cwd=os.path.dirname(os.path.dirname(
                             os.path.abspath(__file__))), env=env)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "OK" in out.stdout
