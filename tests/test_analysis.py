"""HLO static analyzer: loop multipliers and dot accounting vs analytic
ground truth on tiny compiled modules (1 CPU device)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.launch.hlo_analysis import analyze_hlo, count_hlo_ops, roofline


def _compiled_text(fn, *args):
    return jax.jit(fn).lower(*args).compile().as_text()


def test_scan_flops_multiplied_by_trip_count():
    W = jnp.zeros((8, 64, 64), jnp.float32)
    x = jnp.zeros((4, 64), jnp.float32)

    def f(x, W):
        def body(h, w):
            return h @ w, None
        h, _ = jax.lax.scan(body, x, W)
        return h

    st = analyze_hlo(_compiled_text(f, x, W))
    expect = 2 * 4 * 64 * 64 * 8        # 8 iterations of a 4x64x64 matmul
    assert abs(st["flops"] - expect) / expect < 0.05, st["flops"]


def test_plain_matmul_flops_exact():
    a = jnp.zeros((32, 48), jnp.float32)
    b = jnp.zeros((48, 16), jnp.float32)
    st = analyze_hlo(_compiled_text(lambda a, b: a @ b, a, b))
    assert st["flops"] == 2 * 32 * 48 * 16


def test_nested_scan_multiplies():
    x = jnp.zeros((4, 32), jnp.float32)
    W = jnp.zeros((3, 5, 32, 32), jnp.float32)

    def f(x, W):
        def outer(h, ws):
            def inner(h2, w):
                return h2 @ w, None
            h, _ = jax.lax.scan(inner, h, ws)
            return h, None
        h, _ = jax.lax.scan(outer, x, W)
        return h

    st = analyze_hlo(_compiled_text(f, x, W))
    expect = 2 * 4 * 32 * 32 * 15
    assert abs(st["flops"] - expect) / expect < 0.05


def test_traffic_counts_slices_not_buffers():
    """Scan xs access must count slice bytes per iteration, not the array."""
    big = jnp.zeros((64, 1024), jnp.float32)   # 256 KiB

    def f(big):
        def body(acc, row):
            return acc + row.sum(), None
        acc, _ = jax.lax.scan(body, jnp.float32(0), big)
        return acc

    st = analyze_hlo(_compiled_text(f, big))
    # total should be ~ 1 pass over the array (plus small overheads),
    # NOT 64 x array size
    assert st["traffic_bytes"] < 6 * big.size * 4, st["traffic_bytes"]


def test_roofline_terms():
    r = roofline(flops_pd=197e12, bytes_pd=819e9, coll_wire_pd=0.0,
                 model_flops_global=197e12 * 4, n_chips=4)
    assert abs(r["compute_s"] - 1.0) < 1e-9
    assert abs(r["memory_s"] - 1.0) < 1e-9
    assert r["dominant"] in ("compute", "memory")
    assert abs(r["useful_flop_ratio"] - 1.0) < 1e-9


def test_count_hlo_ops():
    a = jnp.zeros((8, 8))
    txt = _compiled_text(lambda a: (a @ a) @ a, a)
    c = count_hlo_ops(txt, ("dot",))
    assert c["dot"] == 2
