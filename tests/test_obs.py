"""Telemetry subsystem (DESIGN.md §9): zero-overhead-when-off contract,
device-counter export parity, event schema, and the shard
skipped-vs-dropped report split.

The zero-cost contract is structural, not just fast: instrumentation
lives only at host call sites around jitted launches, so the traced
programs — and therefore compiled HLO and op outputs — are bit-identical
with telemetry on or off.
"""
import numpy as np
import pytest

from repro import obs
from repro import shard as SH
from repro.core import batch_ops as B
from repro.core import keys as K
from repro.core.faults import FaultPlan, FaultSpec, RetryPolicy
from repro.core.fbtree import TreeConfig, bulk_build
from repro.core.traverse import TraversalEngine

W = 8
FAST = RetryPolicy(max_attempts=2, sleep=lambda s: None)


@pytest.fixture(autouse=True)
def _obs_isolation():
    """Every test starts and ends with telemetry off and empty."""
    obs.disable()
    obs.reset()
    yield
    obs.disable()
    obs.reset()


def _keyset(ints):
    return K.make_keyset([int(x).to_bytes(W, "big") for x in ints], W)


def _tree(n=200, seed=7):
    rng = np.random.default_rng(seed)
    base = np.sort(rng.choice(1 << 40, n, replace=False))
    cfg = TreeConfig.plan(max_keys=1024, key_width=W)
    return (bulk_build(cfg, _keyset(base), np.arange(n, dtype=np.int32)),
            base)


# ------------------------------------------------- zero-overhead contract

def test_disabled_is_bit_identical_and_registers_nothing():
    tree, base = _tree()
    q = _keyset([int(x) for x in base[:64]])
    v0, rep0 = B.lookup_batch(tree, q.bytes, q.lens)
    assert obs.all_metrics() == [] and obs.events() == []

    obs.enable()
    v1, rep1 = B.lookup_batch(tree, q.bytes, q.lens)
    assert np.array_equal(np.asarray(v0), np.asarray(v1))
    assert np.array_equal(np.asarray(rep0.found), np.asarray(rep1.found))
    for f in rep0._fields:
        assert np.array_equal(np.asarray(getattr(rep0, f)),
                              np.asarray(getattr(rep1, f))), f
    assert obs.all_metrics(), "enabled run should register metrics"


def test_jitted_program_is_identical_and_callback_free():
    """The traced lookup program must not change with the obs flag, and
    must contain no host callbacks either way — instrumentation never
    enters jit."""
    tree, base = _tree(n=120)
    q = _keyset([int(x) for x in base[:32]])
    import jax.numpy as jnp
    qb, ql = jnp.asarray(q.bytes), jnp.asarray(q.lens)

    def lowered_text():
        return B._lookup_batch_jit.lower(
            tree, qb, ql, sibling_check=True, engine=None).as_text()

    off = lowered_text()
    obs.enable()
    on = lowered_text()
    assert on == off, "obs flag changed the traced program"
    for marker in ("callback", "CustomCall", "outfeed"):
        assert marker not in off, f"host {marker} in jitted lookup"


def test_null_metrics_while_disabled():
    c = obs.counter("x")
    g = obs.gauge("y")
    h = obs.histogram("z")
    c.inc(), g.set(3.0), h.observe(0.5)
    assert obs.all_metrics() == []
    assert obs.get_metric("x") is None
    assert obs.event("rebalance", n_live=1, reclaimed=0) is None
    assert obs.events() == []


# -------------------------------------------------- device-counter export

def test_drained_counters_match_branchstats_totals():
    """The bridge's registry totals equal the per-lane BranchStats sums
    the parity suite asserts on directly — one device_get, no drift."""
    tree, base = _tree()
    q = _keyset([int(x) for x in base[:96]])
    eng = TraversalEngine("jnp", "tuple", collect_stats=True)
    _, rep = B.lookup_batch(tree, q.bytes, q.lens, engine=eng)

    obs.enable()
    obs.reset()
    _, rep2 = B.lookup_batch(tree, q.bytes, q.lens, engine=eng)
    for f in ("feat_rounds", "suffix_bs", "key_compares", "lines_touched",
              "tag_candidates"):
        want = int(np.asarray(getattr(rep, f)).sum())
        m = obs.get_metric(f"tree.{f}", op="lookup")
        assert m is not None and m.value == want, (f, m and m.value, want)
    m = obs.get_metric("op.found", op="lookup")
    assert m.value == int(np.asarray(rep.found).sum())
    assert obs.get_metric("op.lanes", op="lookup").value == 96


def test_histogram_quantiles_and_prometheus_export():
    obs.enable()
    h = obs.histogram("lat", op="x")
    for v in (1e-5, 1e-4, 1e-3, 1e-2, 0.1, 1.0):
        h.observe(v)
    assert h.count == 6 and h.p50 <= h.p90 <= h.p99
    # geometric-midpoint estimate lands within its log2 bucket (factor 2)
    assert 0.5e-3 <= h.p50 <= 2e-3
    assert 0.5 <= h.p99 <= 2.0
    text = obs.prometheus_text()
    assert '# TYPE lat histogram' in text
    assert 'lat_count{op="x"} 6' in text
    assert 'lat_bucket{op="x",le="+Inf"} 6' in text
    obs.counter("hits", op="x").inc(3)
    assert 'hits{op="x"} 3' in obs.prometheus_text()


def test_spans_nest_and_record_duration():
    obs.enable()
    with obs.span("outer"):
        assert obs.current_path() == "outer"
        with obs.span("inner", shard=1):
            assert obs.current_path() == "outer.inner"
    assert obs.current_path() == ""
    m = obs.get_metric("span.outer.inner", shard=1)
    assert m is not None and m.count == 1 and m.sum > 0
    assert obs.get_metric("span.outer").count == 1


# ------------------------------------------------------------ event log

def test_event_schema_enforced_at_emit():
    obs.enable()
    with pytest.raises(ValueError, match="unknown telemetry event type"):
        obs.event("not-a-type", x=1)
    with pytest.raises(ValueError, match="missing required fields"):
        obs.event("publish", label="x")
    e = obs.event("publish", label="x", version=1, ok=True, reason="",
                  duration_s=0.5)
    assert e["seq"] == 0 and obs.validate_event(e) == []
    assert obs.validate_event({"type": "nope"}) != []
    assert obs.validate_event({"type": "fault", "seq": 1, "ts": 2.0}) != []
    assert obs.event_summary() == {"publish": 1}


def test_publish_event_from_compact_barrier(rng):
    """PrefixCache.compact routes through the lifecycle manager, so one
    compact emits one complete publish event labeled 'compact'."""
    from repro.serving import PrefixCache
    obs.enable()
    pc = PrefixCache(n_pages=64, block_tokens=8, max_keys=2048)
    for _ in range(4):
        toks = rng.integers(0, 500, size=24).astype(np.int32)
        hb, _ = pc.match([toks])
        pc.publish(toks, hb[0])
    rep = pc.compact()
    assert rep.ok
    pubs = [e for e in obs.events() if e["type"] == "publish"]
    assert len(pubs) == 1
    e = pubs[0]
    assert e["label"] == "compact" and e["ok"] and e["version"] == 1
    assert e["duration_s"] > 0 and obs.validate_event(e) == []


def test_fault_events_carry_replay_context():
    obs.enable()
    tree, base = _tree(n=80)
    plan = FaultPlan((FaultSpec("lifecycle.begin", "abort"),), seed=99)
    from repro.core.lifecycle import TreeVersionManager
    mgr = TreeVersionManager(tree, faults=plan)
    rep = mgr.rebuild()
    assert not rep.ok
    faults = [e for e in obs.events() if e["type"] == "fault"]
    assert faults and faults[0]["seed"] == 99
    assert faults[0]["site"] == "lifecycle.begin"
    pubs = [e for e in obs.events() if e["type"] == "publish"]
    assert pubs and not pubs[0]["ok"]
    assert pubs[0]["reason"].startswith("fault:")


# ------------------------------------- shard report: skipped vs dropped

def _sharded(n=120, n_shards=3, seed=5):
    rng = np.random.default_rng(seed)
    base = np.sort(rng.choice(1 << 40, n, replace=False))
    st = SH.sharded_build(_keyset(base), np.arange(n, dtype=np.int32),
                          n_shards, max_keys=1024)
    return st, base


def test_report_separates_healthy_skip_from_drop():
    """A shard that owns no lanes is 'skipped' (healthy); a shard that
    owned lanes but was unreachable is 'dropped'. The two must never be
    conflated — recovery heuristics and counters key off the split."""
    st, base = _sharded()
    # shard 0's keys only: shards 1-2 own no lanes -> healthy skips
    q = _keyset([int(x) for x in base[:32]])
    _, rep = SH.lookup_batch(st, q.bytes, q.lens)
    assert rep.shards_hit == 1
    assert rep.shards_skipped == 2
    assert rep.shards_dropped == ()
    assert not rep.degraded.any() and not rep.failed.any()

    # same query under a sticky drop of shard 0: now it is dropped, and
    # the other two are still just skipped
    st2, _ = _sharded()
    plan = FaultPlan((FaultSpec("shard.dispatch.lookup", "drop_shard",
                                shard=0),))
    _, rep2 = SH.lookup_batch(st2, q.bytes, q.lens, faults=plan,
                              retry=FAST)
    assert rep2.shards_hit == 0
    assert rep2.shards_skipped == 2
    assert rep2.shards_dropped == (0,)
    assert rep2.degraded.all()      # lookups degrade to the snapshot


def test_mutation_report_skipped_vs_dropped_and_counters():
    st, base = _sharded()
    obs.enable()
    q = _keyset([int(x) for x in base[:32]])     # shard 0 only
    vals = np.arange(32, dtype=np.int32)
    plan = FaultPlan((FaultSpec("shard.dispatch.update", "drop_shard",
                                shard=0),))
    _, rep = SH.update_batch(st, q.bytes, q.lens, vals, faults=plan,
                             retry=FAST)
    assert rep.shards_hit == 0
    assert rep.shards_skipped == 2
    assert rep.shards_dropped == (0,)
    assert rep.failed.all()
    assert obs.get_metric("shard.failed_lanes", op="update").value == 32
    assert obs.get_metric("shard.retries", op="update").value > 0
    evs = obs.event_summary()
    assert evs.get("shard.failed") == 1
    assert evs.get("shard.down") == 1
    # healthy skips registered no degradation signal anywhere
    assert obs.get_metric("shard.degraded_lanes", op="update") is None


def test_shard_retry_and_degraded_events():
    st, base = _sharded()
    obs.enable()
    q = _keyset([int(x) for x in base[:32]])
    # one transient drop: absorbed by retry, no degradation
    plan = FaultPlan((FaultSpec("shard.dispatch.lookup", "drop_shard",
                                shard=0, nth=0, count=1),))
    _, rep = SH.lookup_batch(st, q.bytes, q.lens, faults=plan, retry=FAST)
    assert rep.shards_hit == 1 and rep.shards_dropped == ()
    assert not rep.degraded.any()
    retries = [e for e in obs.events() if e["type"] == "shard.retry"]
    assert len(retries) == 1 and retries[0]["shard"] == 0
    assert obs.get_metric("shard.retries", op="lookup").value == 1
    assert obs.event_summary().get("shard.degraded") is None
