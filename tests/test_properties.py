"""Extra hypothesis property suites across subsystem invariants."""
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import HealthCheck, given, settings, strategies as st

from repro.models.attention import MaskSpec, _sdpa_flash, _sdpa_small


@settings(deadline=None, max_examples=12,
          suppress_health_check=list(HealthCheck))
@given(st.integers(8, 96), st.integers(8, 96), st.sampled_from([1, 2, 4]),
       st.sampled_from([8, 16]), st.booleans(), st.integers(0, 24),
       st.integers(0, 2**31 - 1))
def test_flash_equals_exact_attention(S, T, n_rep, hd, causal, window, seed):
    """Online-softmax tiling is exact for arbitrary shapes/masks (rows with
    at least one valid key)."""
    if causal and T < S:
        T = S          # avoid degenerate all-masked rows
    rng = np.random.default_rng(seed)
    Hk = 2
    q = jnp.asarray(rng.standard_normal((1, S, Hk * n_rep, hd)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((1, T, Hk, hd)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((1, T, Hk, hd)), jnp.float32)
    spec = MaskSpec("causal" if causal else "full",
                    window if causal and window >= 8 else 0, 0)
    ref = _sdpa_small(q, k, v, spec, n_rep)
    got = _sdpa_flash(q, k, v, spec, n_rep, q_chunk=32, kv_chunk=16)
    assert float(jnp.abs(ref - got).max()) < 2e-4


@settings(deadline=None, max_examples=15,
          suppress_health_check=list(HealthCheck))
@given(st.integers(1, 64), st.integers(1, 7), st.integers(0, 2**31 - 1))
def test_data_stream_shard_factorizations_agree(batch_mult, step, seed):
    """Any shard factorization reassembles the identical global batch."""
    from repro.train.data import DataConfig, TokenStream
    B = 8 * max(1, batch_mult % 4)
    dc = DataConfig(vocab=512, global_batch=B, seq_len=32, seed=seed)
    s = TokenStream(dc)
    full = s.batch_at(step)["tokens"]
    for n_shards in (1, 2, 4, 8):
        if B % n_shards:
            continue
        parts = [s.shard_batch_at(step, i, n_shards)["tokens"]
                 for i in range(n_shards)]
        assert (np.concatenate(parts) == full).all()


@settings(deadline=None, max_examples=10,
          suppress_health_check=list(HealthCheck))
@given(st.lists(st.integers(-2**63, 2**63 - 1), min_size=2, max_size=64,
                unique=True))
def test_signed_int_tree_order(xs):
    """§3.6 sign-flip codec: the tree's range scan returns signed ints in
    true signed order."""
    from repro.core import batch_ops as B
    from repro.core import keys as K
    from repro.core.fbtree import TreeConfig, bulk_build
    enc = [K.encode_int64(x).tobytes() for x in xs]
    ks = K.make_keyset(enc, 8)
    cfg = TreeConfig.plan(max_keys=4 * len(xs), key_width=8)
    t = bulk_build(cfg, ks, np.arange(len(xs), dtype=np.int32))
    lo = K.make_keyset([K.encode_int64(min(xs)).tobytes()], 8)
    kid, val, emitted, _ = B.range_scan(t, lo.bytes, lo.lens,
                                        max_items=len(xs))
    got_rows = np.asarray(t.arrays.key_bytes)[np.asarray(kid[0][:int(emitted[0])])]
    got = (K.decode_uint64(got_rows[:, :8]).astype(np.uint64)
           ^ np.uint64(1 << 63)).view(np.int64)
    assert list(got) == sorted(xs)


@settings(deadline=None, max_examples=10,
          suppress_health_check=list(HealthCheck))
@given(st.integers(2, 6), st.integers(1, 3), st.integers(0, 2**31 - 1))
def test_mamba2_state_handoff(n_chunks, tail, seed):
    """SSD chunked forward == processing the sequence in two halves with
    explicit state handoff (the prefill→decode contract)."""
    import dataclasses
    from repro.configs import get_config
    from repro.models import mamba as M
    cfg = get_config("zamba2-7b", smoke=True)
    S = 16 * n_chunks
    cut = 16 * (n_chunks - tail) if n_chunks > tail else 16
    rng = np.random.default_rng(seed)
    p = M.mamba2_params(jax.random.PRNGKey(seed % 7), cfg)
    x = jnp.asarray(rng.standard_normal((1, S, cfg.d_model)),
                    jnp.float32).astype(cfg.dtype)
    y_full, st_full = M.mamba2_forward(p, cfg, x, chunk=16)
    y1, st1 = M.mamba2_forward(p, cfg, x[:, :cut], chunk=16)
    y2, st2 = M.mamba2_forward(p, cfg, x[:, cut:], state=st1, chunk=16)
    ycat = jnp.concatenate([y1, y2], axis=1)
    err = float(jnp.abs(ycat.astype(jnp.float32)
                        - y_full.astype(jnp.float32)).max())
    assert err < 3e-2, err
