"""Engine contract: every (backend, layout) combination is observationally
identical — same children at every level, same leaf ids, same
machine-independent BranchStats — on randomized trees drawn from the
benchmark dataset distributions. The matrix includes both backend kinds
(per-level and the ``fused`` whole-descent kernel) and both stats modes:
``collect_stats=False`` must return bit-identical leaf ids/paths while
compiling the counters away (DESIGN.md §3)."""
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import HealthCheck, given, settings, strategies as st

from repro.core import batch_ops as B
from repro.core import keys as K
from repro.core.fbtree import TreeConfig, bulk_build, stack_levels
from repro.core.traverse import (DEFAULT_ENGINE, TraversalEngine,
                                 available_backends, backend_kind,
                                 get_backend, get_descent_backend)

from benchmarks.common import make_dataset

COMBOS = ([(b, l) for b in ("jnp", "pallas") for l in ("tuple", "stacked")]
          + [("fused", "stacked")])

STAT_FIELDS = ("feat_rounds", "suffix_bs", "key_compares", "sibling_hops")


def _build(ds_name, n_keys, seed, fs=4):
    keys, width = make_dataset(ds_name, n_keys, seed=seed)
    ks = K.make_keyset(keys, width)
    cfg = TreeConfig.plan(max_keys=2 * n_keys, key_width=width, fs=fs)
    tree = bulk_build(cfg, ks, np.arange(len(keys), dtype=np.int32))
    return tree, ks


@settings(deadline=None, max_examples=8,
          suppress_health_check=list(HealthCheck))
@given(st.sampled_from(("rand-int", "3-gram", "ycsb", "twitter", "url")),
       st.sampled_from((2, 4)), st.integers(0, 2**31 - 1))
def test_backend_layout_parity(ds_name, fs, seed):
    tree, ks = _build(ds_name, 600, seed % 1000, fs=fs)
    rng = np.random.default_rng(seed)
    # mix of present keys and perturbed (mostly-missing) keys
    idx = rng.integers(0, ks.n, size=192)
    qb = ks.bytes[idx].copy()
    ql = ks.lens[idx].copy()
    flip = rng.random(192) < 0.3
    qb[flip, -1] ^= 0xA5
    qb, ql = jnp.asarray(qb), jnp.asarray(ql)

    results = {}
    for backend, layout in COMBOS:
        eng = TraversalEngine(backend=backend, layout=layout)
        leaf, path, stats = eng.traverse(tree, qb, ql)
        results[(backend, layout)] = (np.asarray(leaf),
                                      [np.asarray(p) for p in path], stats)
    ref_leaf, ref_path, ref_stats = results[("jnp", "tuple")]
    for combo, (leaf, path, stats) in results.items():
        assert (leaf == ref_leaf).all(), (combo, "leaf ids")
        for lvl, (p, rp) in enumerate(zip(path, ref_path)):
            assert (p == rp).all(), (combo, "children at level", lvl)
        for f in STAT_FIELDS:
            a = np.asarray(getattr(stats, f))
            b = np.asarray(getattr(ref_stats, f))
            assert (a == b).all(), (combo, f)


@settings(deadline=None, max_examples=6,
          suppress_health_check=list(HealthCheck))
@given(st.sampled_from(("ycsb", "url")), st.integers(0, 2**31 - 1))
def test_lookup_reports_identical_across_engines(ds_name, seed):
    tree, ks = _build(ds_name, 400, seed % 1000)
    qb = jnp.asarray(ks.bytes[:128])
    ql = jnp.asarray(ks.lens[:128])
    ref = None
    for backend, layout in COMBOS:
        vals, rep = B.lookup_batch(tree, qb, ql,
                                   engine=TraversalEngine(backend, layout))
        sig = (np.asarray(vals), np.asarray(rep.found),
               np.asarray(rep.key_compares), np.asarray(rep.suffix_bs),
               np.asarray(rep.feat_rounds))
        if ref is None:
            ref = sig
            assert sig[1].all()   # all present keys found
        for a, b in zip(ref, sig):
            assert (a == b).all(), (backend, layout)


def test_stacked_matches_tuple_after_inserts():
    """The stacked copy must track the tuple levels through split rounds."""
    KW = 12
    keys = [int(x) for x in range(0, 3000, 3)]
    ks0 = K.make_keyset(keys[:100], KW)
    cfg = TreeConfig.plan(max_keys=8192, key_width=KW, stacked=True)
    t = bulk_build(cfg, ks0, np.arange(100, dtype=np.int32))
    ks = K.make_keyset(keys[100:], KW)
    t, rep, _ = B.insert_batch(t, ks.bytes, ks.lens,
                               np.arange(100, 1000, dtype=np.int32),
                               engine=TraversalEngine("jnp", "stacked"))
    assert int(rep.splits) > 0
    restacked = stack_levels(t.arrays.levels)
    for got, want in zip(t.arrays.stacked, restacked):
        assert (np.asarray(got) == np.asarray(want)).all()
    allk = K.make_keyset(keys, KW)
    v_t, r_t = B.lookup_batch(t, allk.bytes, allk.lens,
                              engine=TraversalEngine("jnp", "tuple"))
    v_s, r_s = B.lookup_batch(t, allk.bytes, allk.lens,
                              engine=TraversalEngine("pallas", "stacked"))
    assert np.asarray(r_t.found).all() and np.asarray(r_s.found).all()
    assert (np.asarray(v_t) == np.asarray(v_s)).all()


@settings(deadline=None, max_examples=6,
          suppress_health_check=list(HealthCheck))
@given(st.sampled_from(("rand-int", "3-gram", "ycsb", "twitter", "url")),
       st.integers(0, 2**31 - 1))
def test_device_built_tree_parity(ds_name, seed):
    """A device-built tree is traversal-equivalent to the host-built tree
    across ALL backend × layout combinations (DESIGN.md §5): same leaves,
    same per-level children, and — for the stats-contract backends — the
    same machine-independent counters as the host-tree reference."""
    keys, width = make_dataset(ds_name, 500, seed=seed % 1000)
    ks = K.make_keyset(keys, width)
    cfg = TreeConfig.plan(max_keys=2 * len(keys), key_width=width)
    vals = np.arange(len(keys), dtype=np.int32)
    th = bulk_build(cfg, ks, vals)
    td = bulk_build(cfg, ks, vals, device=True)

    rng = np.random.default_rng(seed)
    idx = rng.integers(0, ks.n, size=160)
    qb = ks.bytes[idx].copy()
    ql = ks.lens[idx].copy()
    flip = rng.random(160) < 0.3
    qb[flip, -1] ^= 0xA5
    qb, ql = jnp.asarray(qb), jnp.asarray(ql)

    ref_leaf = None
    all_combos = ([(b, l) for b in ("jnp", "pallas", "binary",
                                    "binary+prefix")
                   for l in ("tuple", "stacked")] + [("fused", "stacked")])
    for backend, layout in all_combos:
        eng = TraversalEngine(backend, layout)
        h_leaf, h_path, h_stats = eng.traverse(th, qb, ql)
        d_leaf, d_path, d_stats = eng.traverse(td, qb, ql)
        assert (np.asarray(d_leaf) == np.asarray(h_leaf)).all(), \
            (backend, layout, "leaf ids")
        for lvl, (p, rp) in enumerate(zip(d_path, h_path)):
            assert (np.asarray(p) == np.asarray(rp)).all(), \
                (backend, layout, "children at level", lvl)
        for f in STAT_FIELDS:
            assert (np.asarray(getattr(d_stats, f))
                    == np.asarray(getattr(h_stats, f))).all(), \
                (backend, layout, f)
        # stats-contract backends also agree with each other on leaf ids
        if (backend, layout) in COMBOS:
            if ref_leaf is None:
                ref_leaf = np.asarray(d_leaf)
            assert (np.asarray(d_leaf) == ref_leaf).all(), (backend, layout)


def test_rebuild_preserves_engine_parity():
    """After churn + rebuild, every backend × layout still agrees — the
    rebuilt stacked copy must equal re-deriving it from the tuple levels."""
    KW = 12
    keys = [int(x) for x in range(0, 3000, 3)]
    ks0 = K.make_keyset(keys[:100], KW)
    cfg = TreeConfig.plan(max_keys=8192, key_width=KW, stacked=True)
    t = bulk_build(cfg, ks0, np.arange(100, dtype=np.int32))
    ks = K.make_keyset(keys[100:], KW)
    t, rep, _ = B.insert_batch(t, ks.bytes, ks.lens,
                               np.arange(100, 1000, dtype=np.int32))
    assert int(rep.splits) > 0
    rmk = K.make_keyset(keys[::4], KW)
    t, _ = B.remove_batch(t, rmk.bytes, rmk.lens)
    t, brep = B.rebuild(t)
    assert not bool(brep.error)
    restacked = stack_levels(t.arrays.levels)
    for got, want in zip(t.arrays.stacked, restacked):
        assert (np.asarray(got) == np.asarray(want)).all()
    allk = K.make_keyset(keys, KW)
    ref = None
    for backend, layout in COMBOS:
        v, r = B.lookup_batch(t, allk.bytes, allk.lens,
                              engine=TraversalEngine(backend, layout))
        sig = (np.asarray(v), np.asarray(r.found))
        if ref is None:
            ref = sig
            expect = np.array([i % 4 != 0 for i in range(len(keys))])
            assert (sig[1] == expect).all()
        assert (sig[0] == ref[0]).all() and (sig[1] == ref[1]).all(), \
            (backend, layout)


@settings(deadline=None, max_examples=6,
          suppress_health_check=list(HealthCheck))
@given(st.sampled_from(("rand-int", "ycsb", "url")),
       st.integers(0, 2**31 - 1))
def test_stats_free_path_bit_identical(ds_name, seed):
    """collect_stats=False is observationally identical on leaf ids and
    per-level paths for EVERY engine — level and descent backends alike —
    and returns all-zero counters (the stats machinery compiles away,
    DESIGN.md §3)."""
    tree, ks = _build(ds_name, 500, seed % 1000)
    rng = np.random.default_rng(seed)
    idx = rng.integers(0, ks.n, size=160)
    qb = ks.bytes[idx].copy()
    ql = ks.lens[idx].copy()
    flip = rng.random(160) < 0.3
    qb[flip, -1] ^= 0xA5
    qb, ql = jnp.asarray(qb), jnp.asarray(ql)

    all_combos = COMBOS + [("binary", "tuple"), ("binary+prefix", "stacked")]
    for backend, layout in all_combos:
        on = TraversalEngine(backend, layout, collect_stats=True)
        off = TraversalEngine(backend, layout, collect_stats=False)
        leaf_on, path_on, _ = on.traverse(tree, qb, ql)
        leaf_off, path_off, stats_off = off.traverse(tree, qb, ql)
        assert (np.asarray(leaf_off) == np.asarray(leaf_on)).all(), \
            (backend, layout, "leaf ids")
        for lvl, (p, rp) in enumerate(zip(path_off, path_on)):
            assert (np.asarray(p) == np.asarray(rp)).all(), \
                (backend, layout, "path at level", lvl)
        for f in stats_off._fields:
            assert (np.asarray(getattr(stats_off, f)) == 0).all(), \
                (backend, layout, f)


def test_stats_free_lookup_matches():
    """The full op pipeline (descend + probe, fused or not) returns the
    same values/found under a stats-free engine; counters are zero."""
    tree, ks = _build("ycsb", 500, 3)
    qb = jnp.asarray(ks.bytes[:128])
    ql = jnp.asarray(ks.lens[:128])
    v_ref, r_ref = B.lookup_batch(tree, qb, ql,
                                  engine=TraversalEngine("jnp", "tuple"))
    for backend, layout in COMBOS:
        eng = TraversalEngine(backend, layout, collect_stats=False)
        v, r = B.lookup_batch(tree, qb, ql, engine=eng)
        assert (np.asarray(v) == np.asarray(v_ref)).all(), (backend, layout)
        assert (np.asarray(r.found) == np.asarray(r_ref.found)).all()
        for f in ("feat_rounds", "suffix_bs", "key_compares",
                  "lines_touched", "tag_candidates"):
            assert (np.asarray(getattr(r, f)) == 0).all(), (backend, layout, f)


def test_backend_registry():
    for name in ("jnp", "pallas", "binary", "binary+prefix"):
        assert name in available_backends()
        assert backend_kind(name) == "level"
        assert callable(get_backend(name))
    assert "fused" in available_backends()
    assert backend_kind("fused") == "descent"
    d = get_descent_backend("fused")
    assert callable(d.traverse) and callable(d.traverse_probe)
    with pytest.raises(KeyError):
        get_backend("no-such-backend")
    with pytest.raises(KeyError):
        get_descent_backend("no-such-backend")
    with pytest.raises(ValueError):
        TraversalEngine(backend="no-such-backend")
    assert DEFAULT_ENGINE.backend == "jnp"
    assert DEFAULT_ENGINE.collect_stats
    # descent engines expose the fused traverse+probe hook; level engines
    # don't (batch_ops collapses to one launch only for the former)
    assert TraversalEngine("fused").probe_path() is not None
    assert TraversalEngine("jnp").probe_path() is None
