"""Sharding rules: divisibility-aware parameter/cache specs (AbstractMesh —
no devices needed)."""
import jax
import numpy as np
import pytest
from jax.sharding import AbstractMesh, PartitionSpec as P

from repro.configs import get_config
from repro.models import lm
from repro.parallel import sharding as SH
from repro.train import optim as O


def _abstract_mesh(sizes, names):
    """AbstractMesh across JAX versions: older releases take
    (axis_sizes, axis_names), the installed one takes a shape tuple of
    (name, size) pairs."""
    try:
        return AbstractMesh(tuple(zip(names, sizes)))
    except TypeError:
        return AbstractMesh(tuple(sizes), tuple(names))


MESH = _abstract_mesh((16, 16), ("data", "model"))
MESH3 = _abstract_mesh((2, 16, 16), ("pod", "data", "model"))


def spec(path, shape, mesh=MESH):
    s = SH.param_spec(path, shape, mesh)
    return SH._validate(s, shape, mesh)


def test_embed_vocab_parallel_when_divisible():
    assert spec("embed", (152064, 5120)) == P("model", None)
    # whisper vocab 51865 is NOT divisible by 16 -> replicate
    assert spec("embed", (51865, 1024)) == P(None, None)


def test_attention_head_sharding_divisibility():
    # 48 heads shard; 40 heads don't (GSPMD padding avoided on inputs)
    assert spec("seg0/attn/wq", (32, 6144, 48, 128)) == \
        P(None, None, "model", None)
    assert spec("seg0/attn/wq", (48, 5120, 40, 128)) == \
        P(None, None, None, None)
    # kv=8 on tp=16 -> replicated
    assert spec("seg0/attn/wk", (48, 5120, 8, 128)) == \
        P(None, None, None, None)


def test_mlp_and_moe_specs():
    assert spec("seg0/mlp/wi", (48, 5120, 13824)) == P(None, None, "model")
    assert spec("seg0/mlp/wo", (48, 13824, 5120)) == P(None, "model", None)
    assert spec("seg1/moe/wi", (58, 256, 7168, 2048)) == \
        P(None, "model", None, None)
    assert spec("seg1/moe/router", (58, 7168, 256)) == P(None, None, None)


def test_mamba_specs():
    assert spec("seg0/mixer/in_proj", (64, 4096, 16384)) == \
        P(None, None, "model")
    assert spec("seg0/mixer/out_proj", (64, 8192, 4096)) == \
        P(None, "model", None)
    assert spec("seg0/mixer/A_log", (64, 8192, 16)) == P(None, None, None)


def test_cache_spec_kv_vs_seq_sharding():
    # kv=16 divisible -> shard kv heads
    s = SH.cache_spec("k", (24, 128, 32768, 16, 64), MESH)
    assert s == P(None, ("data",), None, "model", None)
    # kv=8 not divisible -> shard sequence (flash-decoding style)
    s = SH.cache_spec("k", (48, 128, 32768, 8, 128), MESH)
    assert s == P(None, ("data",), "model", None, None)
    # MLA latent cache: shard sequence
    s = SH.cache_spec("ckv", (61, 128, 32768, 512), MESH)
    assert s == P(None, ("data",), "model", None)


def test_all_arch_param_shardings_build():
    for arch in ("qwen3-14b", "deepseek-v3-671b", "zamba2-7b",
                 "whisper-medium", "falcon-mamba-7b"):
        cfg = get_config(arch)
        sds = jax.eval_shape(lambda c=cfg: lm.init_params(
            c, jax.random.PRNGKey(0)))
        shardings = SH.param_shardings(sds, MESH3)
        for (path, leaf), sh in zip(
                jax.tree_util.tree_flatten_with_path(sds)[0],
                jax.tree_util.tree_leaves(shardings)):
            for e, n in zip(sh.spec, leaf.shape):
                if e is None:
                    continue
                names = e if isinstance(e, tuple) else (e,)
                k = 1
                for nm in names:
                    k *= dict(zip(MESH3.axis_names, MESH3.axis_sizes))[nm]
                assert n % k == 0, (arch, path, leaf.shape, sh.spec)


def test_zero_spec_adds_data_axis():
    z = O.zero_spec(P(None, "model"), (13824, 5120), MESH)
    assert z == P("data", "model")
    # dim not divisible -> untouched
    z = O.zero_spec(P(), (7,), MESH)
    assert all(e is None for e in z)   # dim not divisible -> untouched


def test_sharded_params_fraction():
    """TP must actually shard the big weights: per-device bytes ≤ ~1/8 of
    total for a TP-16 dense model (attention may replicate)."""
    cfg = get_config("yi-9b")     # H=32, kv=4, ff 11008=16*688
    sds = jax.eval_shape(lambda: lm.init_params(cfg, jax.random.PRNGKey(0)))
    shardings = SH.param_shardings(sds, MESH)
    total = per_dev = 0
    for (path, leaf), sh in zip(
            jax.tree_util.tree_flatten_with_path(sds)[0],
            jax.tree_util.tree_leaves(shardings)):
        n = int(np.prod(leaf.shape))
        k = 1
        for e in sh.spec:
            if e is not None:
                names = e if isinstance(e, tuple) else (e,)
                for nm in names:
                    k *= dict(zip(MESH.axis_names, MESH.axis_sizes))[nm]
        total += n
        per_dev += n // k
    assert per_dev / total < 0.15, per_dev / total
