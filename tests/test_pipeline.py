"""Pipeline parallelism: GPipe schedule correctness on a 1-stage mesh and
lowering on a multi-stage abstract check (real multi-device run is covered
by the dry-run)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.launch.mesh import make_pipeline_mesh
from repro.parallel.pipeline import pipeline_apply


def test_pipeline_single_stage_matches_sequential():
    mesh = make_pipeline_mesh(1)
    W = jax.random.normal(jax.random.PRNGKey(0), (1, 16, 16))

    def stage(w, x):
        return jnp.tanh(x @ w)

    x = jax.random.normal(jax.random.PRNGKey(1), (8, 16))
    with mesh:
        y = pipeline_apply(mesh, stage, W, x, n_micro=4)
    want = stage(W[0], x)
    assert np.allclose(np.asarray(y), np.asarray(want), atol=1e-5)
