"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret mode."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import keys as K
from repro.core.branch import branch_level
from repro.core.fbtree import TreeConfig, bulk_build
from repro.core.leaf import probe
from repro.kernels.feature_branch.kernel import feature_branch_kernel
from repro.kernels.feature_branch.ops import branch_level_pallas, feature_branch
from repro.kernels.feature_branch.ref import feature_branch_ref
from repro.kernels.leaf_probe.ops import probe_pallas


def _mk_inputs(rng, B, fs, ns, skew=False):
    feats = rng.integers(0, 8 if skew else 256, size=(B, fs, ns),
                         dtype=np.uint8)
    feats.sort(axis=-1)
    qfeat = rng.integers(0, 8 if skew else 256, size=(B, fs), dtype=np.uint8)
    knum = rng.integers(1, ns + 1, size=(B, 1), dtype=np.int32)
    pcmp = rng.integers(-1, 2, size=(B, 1), dtype=np.int32)
    return (jnp.asarray(feats), jnp.asarray(qfeat), jnp.asarray(knum),
            jnp.asarray(pcmp))


@pytest.mark.parametrize("B,fs,ns", [(32, 4, 64), (64, 2, 64), (16, 4, 128),
                                     (128, 8, 64), (256, 1, 32)])
def test_feature_branch_kernel_matches_ref(rng, B, fs, ns):
    for skew in (False, True):
        args = _mk_inputs(rng, B, fs, ns, skew)
        ref = feature_branch_ref(*args)
        tile = min(B, 128)
        got = feature_branch_kernel(*args, tile_b=tile, interpret=True)
        for r, g, name in zip(ref, got,
                              ("idx", "resolved", "lo", "hi", "rounds")):
            # idx is only defined where the kernel resolved the branch
            if name == "idx":
                m = ref[1].astype(bool)
                assert (jnp.where(m, r, 0) == jnp.where(m, g, 0)).all()
            else:
                assert (r == g).all(), name


def test_feature_branch_pad_path(rng):
    args = _mk_inputs(rng, 37, 4, 64)        # B not multiple of tile
    ref = feature_branch_ref(*args)
    got = feature_branch(*args, use_pallas=True)
    m = ref[1].astype(bool)
    assert (jnp.where(m, ref[0], 0) == jnp.where(m, got[0], 0)).all()


@pytest.mark.parametrize("n,width", [(500, 8), (900, 16)])
def test_branch_level_pallas_full_tree(rng, n, width):
    ints = rng.choice(2**48, size=n, replace=False)
    ks = K.make_keyset([int(x) for x in ints], width)
    cfg = TreeConfig.plan(max_keys=2 * n, key_width=width)
    t = bulk_build(cfg, ks, np.arange(n, dtype=np.int32))
    a = t.arrays
    qb, ql = jnp.asarray(ks.bytes[:256]), jnp.asarray(ks.lens[:256])
    node = jnp.zeros((256,), jnp.int32)
    for lvl in a.levels:
        c1, s1 = branch_level(lvl, a.key_bytes, a.key_lens, node, qb, ql)
        c2, s2 = branch_level_pallas(lvl, a.key_bytes, a.key_lens, node,
                                     qb, ql)
        assert (c1 == c2).all()
        assert (s1.feat_rounds == s2.feat_rounds).all()
        node = c1


def test_leaf_probe_kernel(rng):
    n = 700
    ints = rng.choice(2**40, size=n, replace=False)
    ks = K.make_keyset([int(x) for x in ints], 8)
    cfg = TreeConfig.plan(max_keys=2 * n, key_width=8)
    t = bulk_build(cfg, ks, np.arange(n, dtype=np.int32))
    from repro.core.branch import traverse
    qb, ql = jnp.asarray(ks.bytes[:256]), jnp.asarray(ks.lens[:256])
    leaf, _ = traverse(t, qb, ql)
    f1, s1, v1, _ = probe(t, leaf, qb, ql)
    f2, s2, v2, _ = probe_pallas(t, leaf, qb, ql)
    assert (f1 == f2).all() and (s1 == s2).all() and (v1 == v2).all()


def test_fused_descent_kernel_matches_ref(rng):
    """The fused whole-descent kernel vs its composed-primitives oracle
    (kernels/fused_descent/ref.py): same leaves, paths, probe results, and
    stats — in both stats modes and with/without the sibling epilogue."""
    from repro.kernels.fused_descent.ops import (fused_traverse,
                                                 fused_traverse_probe)
    from repro.kernels.fused_descent.ref import (fused_traverse_probe_ref,
                                                 fused_traverse_ref)
    n = 800
    ints = rng.choice(2**48, size=n, replace=False)
    ks = K.make_keyset([int(x) for x in ints], 10)
    cfg = TreeConfig.plan(max_keys=2 * n, key_width=10)
    t = bulk_build(cfg, ks, np.arange(n, dtype=np.int32))
    qb = np.array(ks.bytes[:192])
    qb[::4, -1] ^= 0x5A                      # mix in missing keys
    qb, ql = jnp.asarray(qb), jnp.asarray(ks.lens[:192])

    for sibling in (True, False):
        for cs in (True, False):
            leaf_r, path_r, st_r = fused_traverse_ref(
                t, qb, ql, sibling_check=sibling, collect_stats=cs)
            leaf_k, path_k, st_k = fused_traverse(
                t, qb, ql, sibling_check=sibling, collect_stats=cs)
            assert (np.asarray(leaf_k) == np.asarray(leaf_r)).all()
            for p, rp in zip(path_k, path_r):
                assert (np.asarray(p) == np.asarray(rp)).all()
            if cs:
                for f in st_r._fields:
                    assert (np.asarray(getattr(st_k, f))
                            == np.asarray(getattr(st_r, f))).all(), f
    outs_r = fused_traverse_probe_ref(t, qb, ql)
    outs_k = fused_traverse_probe(t, qb, ql)
    for name, r, k in zip(("leaf", "path", "found", "slot", "val"),
                          outs_r[:5], outs_k[:5]):
        if name == "path":
            for p, rp in zip(k, r):
                assert (np.asarray(p) == np.asarray(rp)).all()
        else:
            assert (np.asarray(k) == np.asarray(r)).all(), name
    for st_r, st_k in zip(outs_r[5:], outs_k[5:]):
        for f in st_r._fields:
            assert (np.asarray(getattr(st_k, f))
                    == np.asarray(getattr(st_r, f))).all(), f


# ---------------------------------------------------------------- flash attn
def test_flash_attention_kernel_sweep(rng):
    import jax
    from repro.kernels.flash_attention.kernel import flash_attention_kernel
    from repro.kernels.flash_attention.ref import flash_attention_ref
    for BH, S, T, hd, hv, bq, bk in [(2, 128, 128, 32, 32, 128, 128),
                                     (4, 256, 384, 64, 32, 128, 128),
                                     (1, 512, 256, 16, 16, 256, 128)]:
        q = jnp.asarray(rng.standard_normal((BH, S, hd)), jnp.float32)
        k = jnp.asarray(rng.standard_normal((BH, T, hd)), jnp.float32)
        v = jnp.asarray(rng.standard_normal((BH, T, hv)), jnp.float32)
        for causal, window, pre in [(True, 0, 0), (False, 0, 0),
                                    (True, 48, 0), (True, 0, 33)]:
            got = flash_attention_kernel(
                q, k, v, scale=hd ** -0.5, kv_len=T, causal=causal,
                window=window, prefix_len=pre, block_q=bq, block_k=bk,
                interpret=True)
            ref = flash_attention_ref(
                q, k, v, scale=hd ** -0.5, kv_len=T, causal=causal,
                window=window, prefix_len=pre)
            assert float(jnp.abs(got - ref).max()) < 1e-4


def test_flash_sdpa_gqa_and_grads(rng):
    import jax
    from repro.kernels.flash_attention.ops import flash_sdpa
    from repro.models.attention import MaskSpec, _sdpa_small
    B, S, H, Hk, hd = 2, 200, 8, 2, 32
    q = jnp.asarray(rng.standard_normal((B, S, H, hd)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, S, Hk, hd)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, S, Hk, hd)), jnp.float32)
    spec = MaskSpec("causal")
    ref = _sdpa_small(q, k, v, spec, 4)
    got = flash_sdpa(q, k, v, spec, 4, hd ** -0.5)
    assert float(jnp.abs(got - ref).max()) < 1e-4
    g1 = jax.grad(lambda q_: (flash_sdpa(q_, k, v, spec, 4, hd ** -0.5)
                              ** 2).sum())(q)
    g2 = jax.grad(lambda q_: (_sdpa_small(q_, k, v, spec, 4) ** 2).sum())(q)
    assert float(jnp.abs(g1 - g2).max()) < 1e-3
