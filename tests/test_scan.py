"""Scan-engine contract (DESIGN.md §6): every scan route — the jnp
chain-walk reference under any descent backend, the always-sort baseline,
and the fused whole-scan kernel — emits bit-identical ``(key_id, value)``
pairs, ascending, starting at the first key >= the query, on ordered and
dirty (lazily-rearranged) leaves alike; the early-exit walk drains chains
completely when ``max_items`` exceeds the live key count; ``rearranged``
counts exactly the dirty leaves visited and compiles away stats-free."""
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import HealthCheck, given, settings, strategies as st

from repro.core import batch_ops as B
from repro.core import keys as K
from repro.core.fbtree import EMPTY, TreeConfig, bulk_build
from repro.core.traverse import TraversalEngine, get_scan_backend

from benchmarks.common import make_dataset

# the scan A/B matrix: jnp reference under both layouts, a level-kernel
# descent feeding the reference walk, and the fused whole-scan kernel
SCAN_COMBOS = [("jnp", "tuple"), ("jnp", "stacked"), ("pallas", "tuple"),
               ("fused", "stacked")]


def _build_churned(ds_name, n_keys, seed, dirty=True):
    """Tree + sorted live-key oracle; ``dirty=True`` in-place-inserts extra
    keys so a fraction of leaves have ``leaf_ordered`` cleared."""
    keys, width = make_dataset(ds_name, n_keys, seed=seed)
    ks = K.make_keyset(keys, width)
    cfg = TreeConfig.plan(max_keys=3 * n_keys, key_width=width)
    tree = bulk_build(cfg, ks, np.arange(len(keys), dtype=np.int32))
    if dirty:
        extra, _ = make_dataset(ds_name, n_keys // 4, seed=seed + 1)
        extra = [k for k in extra if k not in set(keys)]
        if extra:
            eks = K.make_keyset(extra, width)
            tree, _, _ = B.insert_batch(
                tree, eks.bytes, eks.lens,
                np.arange(len(extra), dtype=np.int32) + 10 * n_keys)
    return tree, width


def _oracle(tree):
    """(sorted live key ids, their padded bytes/lens, kid → value map)."""
    a = tree.arrays
    occ = np.asarray(a.leaf_occ)
    kid = np.asarray(a.leaf_keyid)[occ]
    val = np.asarray(a.leaf_val)[occ]
    kb = np.asarray(a.key_bytes)[kid]
    kl = np.asarray(a.key_lens)[kid]
    order = np.lexsort([kl] + [np.asarray(K.pack_words(kb))[:, i]
                               for i in range(K.pack_words(kb).shape[1] - 1,
                                              -1, -1)])
    return kid[order], kb[order], kl[order], dict(zip(kid.tolist(),
                                                      val.tolist()))


def _key_tuple(kb_row, kl):
    return (bytes(kb_row.tobytes()), int(kl))


def _expected(tree, qb_row, ql_row, max_items):
    kid, kb, kl, vmap = _oracle(tree)
    q = _key_tuple(qb_row, ql_row)
    sel = [i for i in range(len(kid)) if _key_tuple(kb[i], kl[i]) >= q]
    sel = sel[:max_items]
    return kid[sel], np.asarray([vmap[int(k)] for k in kid[sel]])


@settings(deadline=None, max_examples=6,
          suppress_health_check=list(HealthCheck))
@given(st.sampled_from(("rand-int", "ycsb", "url")), st.booleans(),
       st.integers(0, 2**31 - 1))
def test_scan_backend_parity(ds_name, dirty, seed):
    """jnp × layouts × pallas-descent × fused kernel, ordered and dirty
    trees: identical pairs, ascending, starting at the first key >= query,
    EMPTY past ``emitted``; ``rearranged`` agrees across backends."""
    tree, width = _build_churned(ds_name, 400, seed % 1000, dirty=dirty)
    a = tree.arrays
    kid_s, kb_s, kl_s, _ = _oracle(tree)
    rng = np.random.default_rng(seed)
    picks = rng.integers(0, len(kid_s), size=24)
    qb = np.asarray(a.key_bytes)[kid_s[picks]].copy()
    ql = np.asarray(a.key_lens)[kid_s[picks]].copy()
    # perturb a third of the queries so scans also start between keys
    flip = rng.random(len(picks)) < 0.33
    qb[flip, -1] ^= 0xA5
    qb, ql = jnp.asarray(qb), jnp.asarray(ql)
    M = 32

    ref = None
    for backend, layout in SCAN_COMBOS:
        eng = TraversalEngine(backend=backend, layout=layout)
        kid, val, em, rearr = B.range_scan(tree, qb, ql, max_items=M,
                                           engine=eng)
        sig = tuple(np.asarray(x) for x in (kid, val, em, rearr))
        if ref is None:
            ref = sig
            # semantic checks against the python oracle on the reference
            for i in range(qb.shape[0]):
                ek, ev = _expected(tree, np.asarray(qb)[i],
                                   int(np.asarray(ql)[i]), M)
                n = int(sig[2][i])
                assert n == len(ek), (backend, i, n, len(ek))
                assert (sig[0][i, :n] == ek).all(), (backend, i)
                assert (sig[1][i, :n] == ev).all(), (backend, i)
                assert (sig[0][i, n:] == EMPTY).all(), (backend, i)
        else:
            for got, want, nm in zip(sig, ref,
                                     ("kid", "val", "emitted", "rearranged")):
                assert (got == want).all(), (backend, layout, nm)
        if not dirty:
            assert (sig[3] == 0).all(), (backend, layout, "rearranged clean")


@settings(deadline=None, max_examples=6,
          suppress_health_check=list(HealthCheck))
@given(st.sampled_from(("rand-int", "ycsb")), st.integers(0, 2**31 - 1))
def test_scan_always_sort_bit_identical(ds_name, seed):
    """The lazy-rearrangement fast path changes nothing observable: the
    always-sort baseline (``force_sort=True``) emits bit-identical pairs."""
    tree, _ = _build_churned(ds_name, 300, seed % 1000, dirty=True)
    kid_s, kb_s, kl_s, _ = _oracle(tree)
    rng = np.random.default_rng(seed)
    picks = rng.integers(0, len(kid_s), size=16)
    qb = jnp.asarray(kb_s[picks])
    ql = jnp.asarray(kl_s[picks])
    eng = TraversalEngine("jnp")
    fast = B.range_scan(tree, qb, ql, max_items=24, engine=eng)
    slow = B._range_scan_jnp(tree, qb, ql, 24, eng, force_sort=True)
    for got, want, nm in zip(slow, fast, ("kid", "val", "emitted",
                                          "rearranged")):
        assert (np.asarray(got) == np.asarray(want)).all(), nm


def test_scan_drains_short_chains():
    """Regression for the old fixed hop bound
    (``ceil(max_items / (leaf_fill // 2)) + 1``): after tombstoning most
    keys, leaves hold far fewer live keys than the bound assumed and a
    ``max_items`` larger than the live set must still drain the WHOLE
    chain. The early-exit while_loop walks to chain end; the old unrolled
    loop under-filled here."""
    KW = 12
    rng = np.random.default_rng(7)
    ints = rng.choice(2**31, size=800, replace=False)
    keys = [int(x) for x in ints]
    ks = K.make_keyset(keys, KW)
    cfg = TreeConfig.plan(max_keys=4000, key_width=KW)
    t = bulk_build(cfg, ks, np.arange(800, dtype=np.int32))
    rm = K.make_keyset(keys[:700], KW)
    t, _ = B.remove_batch(t, rm.bytes, rm.lens)
    live = np.sort(ints[700:].astype(np.uint64))

    s0 = K.make_keyset([int(live[0])], KW)
    for eng in (TraversalEngine("jnp"), TraversalEngine("fused")):
        kid, val, em, _ = B.range_scan(t, s0.bytes, s0.lens, max_items=256,
                                       engine=eng)
        assert int(em[0]) == len(live), (eng.backend, int(em[0]), len(live))
        got = K.decode_uint64(
            np.asarray(t.arrays.key_bytes)[np.asarray(kid[0][:len(live)])][:, :8])
        assert (got == live).all(), eng.backend


def test_scan_rearranged_accounting():
    """``rearranged`` counts the dirty leaves a lane actually visited —
    across ALL hops (the old code only billed hop 0) — is zero on a fresh
    bulk-built tree, zero under a stats-free engine, and identical between
    the jnp reference and the fused kernel."""
    KW = 12
    keys = [int(x) for x in range(0, 4000, 4)]
    ks = K.make_keyset(keys, KW)
    cfg = TreeConfig.plan(max_keys=8192, key_width=KW)
    t = bulk_build(cfg, ks, np.arange(len(keys), dtype=np.int32))
    s = K.make_keyset([0], KW)

    _, _, em, rearr = B.range_scan(t, s.bytes, s.lens, max_items=200)
    assert int(em[0]) == 200
    assert (np.asarray(rearr) == 0).all()          # fresh build: all ordered

    # dirty a mid-chain leaf (in-place fit insert clears leaf_ordered) that
    # a 200-item scan from 0 must cross but the hop-0 leaf does not contain
    ins = K.make_keyset([401], KW)
    t2, _, _ = B.insert_batch(t, ins.bytes, ins.lens,
                              np.asarray([9999], np.int32))
    n_dirty = int((~np.asarray(t2.arrays.leaf_ordered)
                   [:int(t2.arrays.leaf_count)]).sum())
    assert n_dirty == 1
    _, _, _, r_jnp = B.range_scan(t2, s.bytes, s.lens, max_items=200)
    assert int(r_jnp[0]) == 1                      # billed on a later hop
    _, _, _, r_fused = B.range_scan(t2, s.bytes, s.lens, max_items=200,
                                    engine=TraversalEngine("fused"))
    assert (np.asarray(r_fused) == np.asarray(r_jnp)).all()
    # a scan starting past the dirty leaf never visits it
    s2 = K.make_keyset([2000], KW)
    _, _, _, r_far = B.range_scan(t2, s2.bytes, s2.lens, max_items=64)
    assert int(r_far[0]) == 0
    # stats-free engines compile the counter away
    for backend in ("jnp", "fused"):
        _, _, em_off, r_off = B.range_scan(
            t2, s.bytes, s.lens, max_items=200,
            engine=TraversalEngine(backend, collect_stats=False))
        assert int(em_off[0]) == 200, backend
        assert (np.asarray(r_off) == 0).all(), backend


def test_scan_registry():
    """Registry contract: ``fused`` exposes a whole-scan entry, level
    backends fall back to the jnp reference (scan_path is None), and the
    kernel-level oracle (``kernels.fused_scan.ref``) matches the registered
    kernel entry outside the engine dispatch."""
    assert callable(get_scan_backend("fused"))
    assert TraversalEngine("fused").scan_path() is not None
    assert TraversalEngine("jnp").scan_path() is None
    assert TraversalEngine("pallas").scan_path() is None
    assert TraversalEngine("binary").scan_path() is None
    with pytest.raises(KeyError):
        get_scan_backend("no-such-scan-backend")

    from repro.kernels.fused_scan.ops import fused_range_scan
    from repro.kernels.fused_scan.ref import fused_range_scan_ref
    tree, _ = _build_churned("ycsb", 200, 5, dirty=True)
    kid_s, kb_s, kl_s, _ = _oracle(tree)
    qb = jnp.asarray(kb_s[::40][:8])
    ql = jnp.asarray(kl_s[::40][:8])
    got = fused_range_scan(tree, qb, ql, max_items=16)
    want = fused_range_scan_ref(tree, qb, ql, max_items=16)
    for g, w, nm in zip(got, want, ("kid", "val", "emitted", "rearranged")):
        assert (np.asarray(g) == np.asarray(w)).all(), nm
