"""FB+-tree batched ops vs a python dict oracle (randomized + hypothesis)."""
import jax
import numpy as np
import pytest
from hypothesis import HealthCheck, given, settings, strategies as st

from repro.core import batch_ops as B
from repro.core import keys as K
from repro.core.fbtree import TreeConfig, bulk_build

KW = 12


def assert_trees_equal(ta, tb, label=""):
    """Bit-exact TreeArrays equality (the DESIGN.md §5 parity contract)."""
    la = jax.tree_util.tree_leaves(ta.arrays)
    lb = jax.tree_util.tree_leaves(tb.arrays)
    assert len(la) == len(lb)
    for i, (a, b) in enumerate(zip(la, lb)):
        a, b = np.asarray(a), np.asarray(b)
        assert a.dtype == b.dtype, (label, i, a.dtype, b.dtype)
        assert a.shape == b.shape, (label, i, a.shape, b.shape)
        assert (a == b).all(), (label, f"array leaf {i} differs")


def build(keys, vals, cap=None):
    ks = K.make_keyset(keys, KW)
    cfg = TreeConfig.plan(max_keys=cap or max(64, 4 * len(keys)), key_width=KW)
    return bulk_build(cfg, ks, np.asarray(vals, np.int32))


def lookup_all(tree, keys):
    ks = K.make_keyset(keys, KW)
    vals, rep = B.lookup_batch(tree, ks.bytes, ks.lens)
    return np.asarray(vals), np.asarray(rep.found)


@settings(deadline=None, max_examples=20,
          suppress_health_check=list(HealthCheck))
@given(st.sets(st.binary(min_size=1, max_size=KW), min_size=1, max_size=200))
def test_bulk_build_lookup(keyset):
    keys = sorted(keyset)
    vals = np.arange(len(keys), dtype=np.int32)
    t = build(keys, vals)
    got, found = lookup_all(t, keys)
    assert found.all()
    assert (got == vals).all()
    missing = [k + b"\xff" for k in keys if len(k) < KW][:50]
    if missing:
        missing = [m for m in missing if m not in keyset]
        if missing:
            _, f2 = lookup_all(t, missing)
            assert not f2.any()


@settings(deadline=None, max_examples=10,
          suppress_health_check=list(HealthCheck))
@given(st.data())
def test_mixed_ops_vs_oracle(data):
    universe = [bytes([a, b]) for a in range(16, 48) for b in range(4)]
    init = data.draw(st.sets(st.sampled_from(universe), min_size=4,
                             max_size=40))
    keys = sorted(init)
    oracle = {k: i for i, k in enumerate(keys)}
    t = build(keys, list(oracle.values()), cap=1024)
    for _ in range(3):
        batch = data.draw(st.lists(st.sampled_from(universe), min_size=1,
                                   max_size=32))
        op = data.draw(st.sampled_from(["insert", "update", "remove"]))
        ks = K.make_keyset(batch, KW)
        vals = np.arange(len(batch), dtype=np.int32) + 1000
        if op == "insert":
            t, rep, _ = B.insert_batch(t, ks.bytes, ks.lens, vals)
            for i, k in enumerate(batch):
                oracle[k] = int(vals[i])   # later op in batch wins ties:
            # dedupe_last_wins: highest seq wins => python dict order matches
        elif op == "update":
            t, rep = B.update_batch(t, ks.bytes, ks.lens, vals)
            for i, k in enumerate(batch):
                if k in oracle:
                    oracle[k] = int(vals[i])
        else:
            t, rep = B.remove_batch(t, ks.bytes, ks.lens)
            for k in batch:
                oracle.pop(k, None)
        got, found = lookup_all(t, universe)
        for i, k in enumerate(universe):
            if k in oracle:
                assert found[i], f"lost key {k!r} after {op}"
                assert got[i] == oracle[k], f"wrong val for {k!r}"
            else:
                assert not found[i], f"phantom key {k!r}"


def test_insert_monotone_append(rng):
    """Monotone insert pattern (worst case for rightmost-leaf funneling)."""
    keys = [int(x) for x in range(0, 2000, 2)]
    t = build(keys[:100], np.arange(100), cap=8192)
    ks = K.make_keyset(keys[100:], KW)
    t, rep, rounds = B.insert_batch(t, ks.bytes, ks.lens,
                                    np.arange(100, 1000, dtype=np.int32))
    got, found = lookup_all(t, keys)
    assert found.all()


def test_range_scan_vs_sorted(rng):
    ints = rng.choice(2**32, size=800, replace=False)
    keys = [int(x) for x in ints]
    t = build(keys, np.arange(800))
    srt = np.sort(ints.astype(np.uint64))
    starts = [int(srt[i]) for i in (0, 100, 700, 795)]
    sks = K.make_keyset(starts, KW)
    kid, val, emitted, _ = B.range_scan(t, sks.bytes, sks.lens, max_items=24)
    kb = np.asarray(t.arrays.key_bytes)
    for i, s in enumerate(starts):
        expect = srt[srt >= s][:24]
        n = int(emitted[i])
        assert n == len(expect)
        got = K.decode_uint64(kb[np.asarray(kid[i][:n])][:, :8])
        assert (got == expect).all()


def test_version_semantics():
    """Insert/remove bump leaf versions; update does not (paper §4.2)."""
    keys = [int(x) for x in range(200)]
    t = build(keys, np.arange(200), cap=2048)
    v0 = np.asarray(t.arrays.leaf_version).copy()
    ks = K.make_keyset(keys[:50], KW)
    t2, _ = B.update_batch(t, ks.bytes, ks.lens,
                           np.arange(50, dtype=np.int32))
    assert (np.asarray(t2.arrays.leaf_version) == v0).all()
    t3, _ = B.remove_batch(t2, ks.bytes, ks.lens)
    assert np.asarray(t3.arrays.leaf_version).sum() > v0.sum()


@settings(deadline=None, max_examples=10,
          suppress_health_check=list(HealthCheck))
@given(st.sets(st.binary(min_size=1, max_size=KW), min_size=1, max_size=300),
       st.sampled_from((2, 4)))
def test_device_build_equals_host(keyset, fs):
    """bulk_build(device=True) is bit-identical to the host numpy build."""
    keys = sorted(keyset)
    vals = np.arange(len(keys), dtype=np.int32)
    ks = K.make_keyset(keys, KW)
    cfg = TreeConfig.plan(max_keys=max(64, 2 * len(keys)), key_width=KW,
                          fs=fs)
    th = bulk_build(cfg, ks, vals)
    td = bulk_build(cfg, ks, vals, device=True)
    assert_trees_equal(th, td, "host vs device build")


@settings(deadline=None, max_examples=8,
          suppress_health_check=list(HealthCheck))
@given(st.data())
def test_rebuild_then_traverse(data):
    """rebuild() compacts a churned tree into exactly the tree a fresh
    bulk_build of the live key set would produce, and lookups still match
    the oracle afterwards."""
    universe = [bytes([a, b]) for a in range(16, 48) for b in range(4)]
    init = data.draw(st.sets(st.sampled_from(universe), min_size=8,
                             max_size=60))
    keys = sorted(init)
    oracle = {k: i for i, k in enumerate(keys)}
    t = build(keys, list(oracle.values()), cap=1024)
    for _ in range(2):
        ins = data.draw(st.lists(st.sampled_from(universe), min_size=1,
                                 max_size=48))
        ks = K.make_keyset(ins, KW)
        vals = np.arange(len(ins), dtype=np.int32) + 5000
        t, _, _ = B.insert_batch(t, ks.bytes, ks.lens, vals)
        for i, k in enumerate(ins):
            oracle[k] = int(vals[i])
        rm = data.draw(st.lists(st.sampled_from(universe), min_size=1,
                                max_size=24))
        ks = K.make_keyset(rm, KW)
        t, _ = B.remove_batch(t, ks.bytes, ks.lens)
        for k in rm:
            oracle.pop(k, None)

    t2, rep = B.rebuild(t)
    assert not bool(rep.error)
    assert int(rep.n_live) == len(oracle)
    # fresh-build leaf occupancy (a dense 64-key leaf may re-chunk into two)
    fill = t.config.leaf_fill
    assert int(t2.arrays.leaf_count) == max(1, -(-len(oracle) // fill))
    assert int(t2.arrays.key_count) == len(oracle)   # pool re-packed
    assert (np.asarray(t2.arrays.leaf_version) == 0).all()

    got, found = lookup_all(t2, universe)
    for i, k in enumerate(universe):
        if k in oracle:
            assert found[i] and got[i] == oracle[k], f"key {k!r} after rebuild"
        else:
            assert not found[i], f"phantom key {k!r} after rebuild"

    # the rebuilt tree IS the bulk-built tree of the live set (host & device)
    live = sorted(oracle)
    ks = K.make_keyset(live, KW)
    vals = np.asarray([oracle[k] for k in live], np.int32)
    ref = bulk_build(t.config, ks, vals)
    assert_trees_equal(t2, ref, "rebuild vs fresh host build")

    # rebuild is idempotent
    t3, rep3 = B.rebuild(t2)
    assert int(rep3.reclaimed) == 0
    assert_trees_equal(t3, t2, "rebuild idempotence")


def test_capacity_error_raises():
    keys = [int(x) for x in range(60)]
    ks = K.make_keyset(keys, KW)
    cfg = TreeConfig.plan(max_keys=64, key_width=KW)
    t = bulk_build(cfg, ks, np.arange(60, dtype=np.int32))
    big = K.make_keyset([int(x) for x in range(100, 400)], KW)
    with pytest.raises(RuntimeError):
        B.insert_batch(t, big.bytes, big.lens,
                       np.arange(300, dtype=np.int32))
