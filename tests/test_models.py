"""Per-arch smoke tests (reduced configs): forward/train-step shapes, no
NaNs, prefill/decode vs teacher-forced forward."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_IDS, get_config, smoke_batch
from repro.models import lm
from repro.models.layers import softmax_xent
from repro.train.optim import OptConfig
from repro.train.train_step import init_state, make_train_step


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_shapes_no_nan(arch):
    cfg = get_config(arch, smoke=True)
    p = lm.init_params(cfg, jax.random.PRNGKey(0))
    batch = smoke_batch(cfg, B=2, S=24)
    logits, aux, h = lm.forward(p, cfg, batch)
    S_out = batch["tokens"].shape[1] + (cfg.n_patches if cfg.family == "vlm"
                                        else 0)
    assert logits.shape == (2, S_out, cfg.vocab)
    assert not bool(jnp.isnan(logits.astype(jnp.float32)).any())
    P = cfg.n_patches if cfg.family == "vlm" else 0
    loss = softmax_xent(logits[:, P:-1], batch["tokens"][:, 1:])
    assert 4.0 < float(loss) < 9.0      # ~ln(512)=6.24 at init


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step_decreases_loss(arch):
    cfg = get_config(arch, smoke=True)
    ocfg = OptConfig(lr=5e-3, warmup=1, total_steps=50)
    state = init_state(cfg, ocfg, jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(cfg, ocfg))
    batch = smoke_batch(cfg, B=2, S=16)
    batch = {k: jnp.asarray(v) for k, v in batch.items()}
    losses = []
    for _ in range(8):                  # overfit one tiny batch
        state, m = step(state, batch)
        losses.append(float(m["loss"]))
        assert not jnp.isnan(m["loss"]), arch
    assert losses[-1] < losses[0], losses


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_decode_matches_forward(arch):
    cfg = get_config(arch, smoke=True)
    if cfg.n_experts:                   # capacity drops never fire ->
        cfg = dataclasses.replace(cfg, capacity_factor=8.0)  # exact match
    p = lm.init_params(cfg, jax.random.PRNGKey(0))
    batch = smoke_batch(cfg, B=2, S=24)
    toks = batch["tokens"]
    P = cfg.n_patches if cfg.family == "vlm" else 0
    logits, _, _ = lm.forward(p, cfg, batch)
    b2 = dict(batch, tokens=toks[:, :20])
    pl, cache = lm.prefill(p, cfg, b2, S_max=32)
    assert float(jnp.abs(pl - logits[:, P + 19]).max()) < 0.15
    for t in range(20, 24):
        pos = jnp.full((2,), t + P, jnp.int32)
        dl, cache = lm.decode_step(p, cfg, toks[:, t], pos, cache)
        err = float(jnp.abs(dl - logits[:, P + t]).max())
        assert err < 0.15, (arch, t, err)


def test_param_counts_match_eval_shape():
    """config.param_count() vs actual tree size (tolerance: small norms)."""
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        sds = jax.eval_shape(lambda c=cfg: lm.init_params(
            c, jax.random.PRNGKey(0)))
        import math
        actual = sum(math.prod(l.shape)
                     for l in jax.tree_util.tree_leaves(sds))
        declared, _ = cfg.param_count()
        rel = abs(actual - declared) / actual
        assert rel < 0.06, (arch, actual, declared, rel)


def test_moe_scatter_matches_gshard():
    import numpy as np
    from repro.models import moe as MOE
    cfg = get_config("deepseek-v3-671b", smoke=True, capacity_factor=8.0)
    key = jax.random.PRNGKey(1)
    p = MOE.moe_params(key, cfg)
    x = jax.random.normal(jax.random.PRNGKey(2), (2, 16, cfg.d_model),
                          jnp.float32).astype(cfg.dtype)
    y1, a1 = MOE.moe_scatter(p, cfg, x)
    y2, a2 = MOE.moe_gshard(p, cfg, x)
    assert float(jnp.abs(y1.astype(jnp.float32)
                         - y2.astype(jnp.float32)).max()) < 1e-2


def test_mamba_chunked_invariance():
    """mamba forward must not depend on chunk size (scan correctness)."""
    from repro.models import mamba as M
    cfg = get_config("falcon-mamba-7b", smoke=True)
    p = M.mamba1_params(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 64, cfg.d_model),
                          jnp.float32).astype(cfg.dtype)
    y1, s1 = M.mamba1_forward(p, cfg, x, chunk=8)
    y2, s2 = M.mamba1_forward(p, cfg, x, chunk=64)
    assert float(jnp.abs(y1.astype(jnp.float32)
                         - y2.astype(jnp.float32)).max()) < 2e-2
    cfg2 = get_config("zamba2-7b", smoke=True)
    p2 = M.mamba2_params(jax.random.PRNGKey(0), cfg2)
    x2 = jax.random.normal(jax.random.PRNGKey(1), (2, 64, cfg2.d_model),
                           jnp.float32).astype(cfg2.dtype)
    z1, _ = M.mamba2_forward(p2, cfg2, x2, chunk=8)
    z2, _ = M.mamba2_forward(p2, cfg2, x2, chunk=32)
    assert float(jnp.abs(z1.astype(jnp.float32)
                         - z2.astype(jnp.float32)).max()) < 2e-2


def test_flash_attention_matches_small():
    import repro.models.attention as A
    q = jax.random.normal(jax.random.PRNGKey(0), (2, 200, 4, 16))
    k = jax.random.normal(jax.random.PRNGKey(1), (2, 200, 2, 16))
    v = jax.random.normal(jax.random.PRNGKey(2), (2, 200, 2, 16))
    for spec in (A.MaskSpec("causal"), A.MaskSpec("full"),
                 A.MaskSpec("causal", 32, 0), A.MaskSpec("causal", 0, 13)):
        ref = A._sdpa_small(q, k, v, spec, 2)
        got = A._sdpa_flash(q, k, v, spec, 2, q_chunk=64, kv_chunk=48)
        assert float(jnp.abs(ref - got).max()) < 1e-4, spec
