"""Training substrate: optimizers, schedule, clipping, compression,
checkpointing, data determinism, restartable loop."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import lm
from repro.parallel import compression as C
from repro.train import checkpoint as CK
from repro.train import ft
from repro.train.data import DataConfig, TokenStream
from repro.train import optim as O
from repro.train.train_step import init_state, make_train_step


def test_schedule_warmup_and_cosine():
    cfg = O.OptConfig(lr=1.0, warmup=10, total_steps=110, min_lr_frac=0.1)
    assert float(O.schedule(cfg, jnp.int32(0))) == 0.0
    assert abs(float(O.schedule(cfg, jnp.int32(10))) - 1.0) < 1e-6
    end = float(O.schedule(cfg, jnp.int32(110)))
    assert abs(end - 0.1) < 1e-6


def test_clip_by_global_norm():
    g = {"a": jnp.full((4,), 3.0), "b": jnp.full((4,), 4.0)}
    clipped, gn = O.clip_by_global_norm(g, 1.0)
    assert abs(float(gn) - 10.0) < 1e-5
    total = jnp.sqrt(sum(jnp.sum(x * x) for x in
                         jax.tree_util.tree_leaves(clipped)))
    assert abs(float(total) - 1.0) < 1e-5


@pytest.mark.parametrize("kind", ["adamw", "adafactor"])
def test_optimizer_reduces_quadratic(kind):
    cfg = O.OptConfig(kind=kind, lr=0.1, warmup=1, total_steps=200,
                      weight_decay=0.0)
    params = {"w": jnp.array([[5.0, -3.0], [2.0, 8.0]])}
    state = O.opt_init(cfg, params)
    for _ in range(100):
        grads = {"w": 2 * params["w"]}
        params, state, m = O.opt_update(cfg, params, grads, state)
    assert float(jnp.abs(params["w"]).max()) < 1.0


def test_compression_error_feedback_unbiased():
    rng = np.random.default_rng(0)
    g = jnp.asarray(rng.standard_normal(1000).astype(np.float32))
    eb = jnp.zeros_like(g)
    acc_q = jnp.zeros_like(g)
    acc = jnp.zeros_like(g)
    for _ in range(50):
        (q,), (eb,) = C.compress_grads_ef((g,), (eb,))
        acc_q = acc_q + q
        acc = acc + g
    # error feedback: accumulated quantized grads track accumulated grads
    rel = float(jnp.linalg.norm(acc_q - acc) / jnp.linalg.norm(acc))
    assert rel < 0.02


def test_quantize_roundtrip_bounded():
    x = jnp.asarray(np.random.default_rng(1).standard_normal(777),
                    dtype=jnp.float32)
    q, s = C.quantize_int8(x)
    y = C.dequantize_int8(q, s, x.shape)
    assert float(jnp.abs(x - y).max()) <= float(s.max()) * 0.51 + 1e-6


def test_checkpoint_roundtrip(tmp_path):
    cfg = get_config("yi-9b", smoke=True)
    ocfg = O.OptConfig()
    state = init_state(cfg, ocfg, jax.random.PRNGKey(0))
    CK.save(str(tmp_path), 7, state)
    template = jax.eval_shape(lambda: init_state(cfg, ocfg,
                                                 jax.random.PRNGKey(0)))
    restored, step = CK.restore(str(tmp_path), template)
    assert step == 7
    for a, b in zip(jax.tree_util.tree_leaves(state),
                    jax.tree_util.tree_leaves(restored)):
        assert np.allclose(np.asarray(a, np.float32),
                           np.asarray(b, np.float32))


def test_checkpoint_keep_k(tmp_path):
    cfg = get_config("yi-9b", smoke=True)
    state = {"x": jnp.zeros((3,))}
    for s in (1, 2, 3, 4, 5):
        CK.save(str(tmp_path), s, state, keep=2)
    assert CK.latest_step(str(tmp_path)) == 5
    dirs = sorted(os.listdir(tmp_path))
    assert len([d for d in dirs if d.startswith("step_")]) == 2


def test_data_deterministic_and_seekable():
    dc = DataConfig(vocab=1000, global_batch=8, seq_len=64, seed=3)
    s1 = TokenStream(dc)
    s2 = TokenStream(dc)
    b1 = s1.batch_at(17)
    b2 = s2.batch_at(17)
    assert (b1["tokens"] == b2["tokens"]).all()
    # shard slices reassemble the global batch for ANY factorization
    full = s1.batch_at(5)["tokens"]
    for n_shards in (2, 4, 8):
        parts = [s1.shard_batch_at(5, i, n_shards)["tokens"]
                 for i in range(n_shards)]
        assert (np.concatenate(parts) == full).all()


def test_run_with_restarts_recovers(tmp_path):
    """Injected failure -> restore from checkpoint -> finish all steps."""
    cfg = get_config("yi-9b", smoke=True)
    ocfg = O.OptConfig(lr=1e-3, warmup=2, total_steps=12)
    data = TokenStream(DataConfig(vocab=cfg.vocab, global_batch=2,
                                  seq_len=16, seed=0), cfg)
    step_fn = jax.jit(make_train_step(cfg, ocfg))
    box = {}
    plan = ft.FailurePlan({6: "injected-node-loss"})

    def make_runner(start):
        if CK.latest_step(str(tmp_path)) is not None:
            template = jax.eval_shape(
                lambda: init_state(cfg, ocfg, jax.random.PRNGKey(0)))
            box["state"], _ = CK.restore(str(tmp_path), template)
        else:
            box["state"] = init_state(cfg, ocfg, jax.random.PRNGKey(0))

        def run(step):
            plan.check(step)
            b = {k: jnp.asarray(v) for k, v in data.batch_at(step).items()}
            box["state"], m = step_fn(box["state"], b)
            return float(m["loss"])
        return run

    log = ft.run_with_restarts(
        12, make_runner, save_every=4,
        saver=lambda s: CK.save(str(tmp_path), s, box["state"]),
        restorer=lambda: CK.latest_step(str(tmp_path)) or 0)
    assert len(log["restarts"]) == 1
    assert max(log["losses"]) > 0
    assert sorted(log["losses"])[-1] == 11


def test_watchdog_flags_straggler():
    wd = ft.Watchdog(window=16, z_thresh=4.0)
    for i in range(20):
        wd.observe(i, 1.0 + 0.01 * (i % 3))
    assert wd.observe(20, 5.0)
    assert wd.stragglers[-1]["step"] == 20


def test_microbatch_accumulation_matches_full_batch():
    cfg = get_config("yi-9b", smoke=True)
    ocfg = O.OptConfig(lr=1e-3, warmup=1, total_steps=10)
    state1 = init_state(cfg, ocfg, jax.random.PRNGKey(0))
    state2 = jax.tree_util.tree_map(lambda x: x, state1)
    data = TokenStream(DataConfig(vocab=cfg.vocab, global_batch=4,
                                  seq_len=16, seed=0), cfg)
    b = {k: jnp.asarray(v) for k, v in data.batch_at(0).items()}
    s1 = make_train_step(cfg, ocfg, n_micro=1)
    s2 = make_train_step(cfg, ocfg, n_micro=2)
    out1, m1 = s1(state1, b)
    out2, m2 = s2(state2, b)
    for a, bb in zip(jax.tree_util.tree_leaves(out1["params"]),
                     jax.tree_util.tree_leaves(out2["params"])):
        assert np.allclose(np.asarray(a, np.float32),
                           np.asarray(bb, np.float32), atol=2e-2), \
            "microbatched step diverged from full batch"
