"""Key codec properties: order preservation is the §3.6 cornerstone."""
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core import keys as K


@given(st.lists(st.integers(0, 2**64 - 1), min_size=2, max_size=50))
def test_uint64_order_preserving(xs):
    enc = K.encode_uint64(np.asarray(xs, dtype=np.uint64))
    order_int = np.argsort(np.asarray(xs, dtype=np.uint64), kind="stable")
    rows = [bytes(e) for e in enc]
    order_bytes = sorted(range(len(rows)), key=lambda i: (rows[i], i))
    assert list(order_int) == order_bytes


@given(st.lists(st.integers(-2**63, 2**63 - 1), min_size=2, max_size=50))
def test_int64_signflip_order_preserving(xs):
    enc = K.encode_int64(np.asarray(xs, dtype=np.int64))
    rows = [bytes(e) for e in enc]
    order_int = sorted(range(len(xs)), key=lambda i: (xs[i], i))
    order_bytes = sorted(range(len(rows)), key=lambda i: (rows[i], i))
    assert order_int == order_bytes


@given(st.integers(0, 2**64 - 1))
def test_uint64_roundtrip(x):
    assert int(K.decode_uint64(K.encode_uint64(x))) == x


@given(st.lists(st.binary(min_size=0, max_size=12), min_size=1,
                max_size=40, unique=True))
def test_lex_sort_matches_python(keys):
    ks = K.make_keyset(keys, max_key_len=12)
    idx = K.lex_sort_indices(ks)
    got = [keys[i] for i in idx]
    assert got == sorted(keys)


@given(st.lists(st.binary(min_size=1, max_size=10), min_size=2, max_size=20))
def test_compare_padded_matches_python(keys):
    ks = K.make_keyset(keys, max_key_len=10)
    n = len(keys)
    a = ks.bytes[:, None, :].repeat(n, 1).reshape(n * n, -1)
    al = ks.lens[:, None].repeat(n, 1).reshape(-1)
    b = np.tile(ks.bytes, (n, 1))
    bl = np.tile(ks.lens, n)
    c = K.compare_padded(a, al, b, bl).reshape(n, n)
    for i in range(n):
        for j in range(n):
            want = (keys[i] > keys[j]) - (keys[i] < keys[j])
            assert c[i, j] == want


def test_tags_deterministic_and_spread(rng):
    keys = [bytes(rng.integers(0, 256, size=rng.integers(1, 16),
                               dtype=np.uint8)) for _ in range(512)]
    ks = K.make_keyset(list(dict.fromkeys(keys)), 16)
    t1 = K.fnv1a_tags(ks.bytes, ks.lens)
    t2 = K.fnv1a_tags(ks.bytes, ks.lens)
    assert (t1 == t2).all()
    # fingerprints should use most of the byte range
    assert len(np.unique(t1)) > 64
