"""End-to-end behaviour: train loop with checkpoint/restart, sharded train
step on a local production-axis mesh, dry-run cell as a subprocess."""
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.launch.mesh import make_local_mesh
from repro.launch.train import train_loop


def test_train_loop_learns_and_restarts(tmp_path):
    cfg = get_config("yi-9b", smoke=True)
    out = train_loop(cfg, steps=30, batch=4, seq=32, ckpt_dir=str(tmp_path),
                     save_every=10, lr=3e-3, inject_failure=17, log_every=100)
    losses = sorted(out["losses"].items())
    assert len(out["restarts"]) == 1
    first = np.mean([l for _, l in losses[:5]])
    last = np.mean([l for _, l in losses[-5:]])
    assert last < first, (first, last)


def test_train_loop_microbatch_and_compression():
    cfg = get_config("yi-9b", smoke=True)
    out = train_loop(cfg, steps=6, batch=4, seq=32, n_micro=2, compress=True,
                     log_every=100)
    assert all(np.isfinite(l) for l in out["losses"].values())


def test_local_mesh_sharded_train_step():
    """The production train-step code path (shardings + constraints) on a
    1-device mesh with production axis names."""
    from repro.parallel import sharding as SH
    from repro.train import optim as O
    from repro.train.train_step import init_state, make_train_step
    cfg = get_config("qwen3-14b", smoke=True)
    mesh = make_local_mesh(("data", "model"))
    ocfg = O.OptConfig(lr=1e-3, warmup=1, total_steps=10)
    state = init_state(cfg, ocfg, jax.random.PRNGKey(0))
    step = make_train_step(cfg, ocfg, shard=SH.shard)
    with mesh, SH.ShardCtx(mesh):
        jstep = jax.jit(step)
        toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0,
                                  cfg.vocab, jnp.int32)
        state, m = jstep(state, {"tokens": toks})
    assert np.isfinite(float(m["loss"]))


def test_dryrun_single_cell_smoke():
    """Lower+compile one production cell exactly as the launcher does (the
    512-virtual-device env only exists in the subprocess)."""
    code = (
        "from repro.launch.dryrun import run_cell; import json; "
        "r = run_cell('paligemma-3b', 'decode_32k', 'single'); "
        "print(json.dumps({'status': r['status'], "
        "'dom': r.get('roofline', {}).get('dominant')}))"
    )
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, timeout=560, env=env, cwd=".")
    assert out.returncode == 0, out.stderr[-2000:]
    rec = json.loads(out.stdout.strip().splitlines()[-1])
    assert rec["status"] == "ok"
