import numpy as np
import pytest

# NOTE: no XLA_FLAGS here — smoke tests must see the real (1-device) CPU;
# only launch/dryrun.py forces 512 host devices (per assignment brief).


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0xFB)
