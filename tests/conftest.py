import numpy as np
import pytest

# NOTE: no XLA_FLAGS here — smoke tests must see the real (1-device) CPU;
# only launch/dryrun.py forces 512 host devices (per assignment brief).

# Property suites import hypothesis; hermetic containers can't pip-install
# it, so fall back to the bundled sampler (no-op when the real one exists).
from repro._compat.hypothesis_fallback import install as _install_hypothesis

_install_hypothesis()


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0xFB)
