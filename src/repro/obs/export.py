"""Telemetry exporters (DESIGN.md §9): JSON-lines events, Prometheus
text, and a console summary table.

All three read the process-global registry/event log and work with
collection disabled (export after the run is the normal shape — e.g. the
chaos sweep dumps the event log only when a schedule fails).
"""
from __future__ import annotations

import json
import os
from typing import List, Optional

from . import registry as _reg
from .events import event_summary as _event_summary
from .events import events as _all_events

__all__ = ["export_events_jsonl", "prometheus_text", "console_summary"]


def export_events_jsonl(path: str) -> int:
    """Write the event log as JSON lines (one event per line, emit
    order); returns the number of events written. Parent directories are
    created — exports land next to CI artifacts like failing chaos
    seeds."""
    d = os.path.dirname(path)
    if d:
        os.makedirs(d, exist_ok=True)
    evs = _all_events()
    with open(path, "w", encoding="utf-8") as f:
        for e in evs:
            f.write(json.dumps(e, sort_keys=True) + "\n")
    return len(evs)


def _fmt_labels(labels) -> str:
    if not labels:
        return ""
    inner = ",".join(f'{k}="{v}"' for k, v in labels)
    return "{" + inner + "}"


def _prom_name(name: str) -> str:
    return name.replace(".", "_").replace("-", "_")


def prometheus_text() -> str:
    """The registry in Prometheus text exposition format. Histograms emit
    the standard cumulative ``_bucket{le=...}`` ladder over the shared
    log2 bounds plus ``_sum``/``_count``."""
    lines: List[str] = []
    seen_type = set()
    for m in _reg.all_metrics():
        pname = _prom_name(m.name)
        if pname not in seen_type:
            seen_type.add(pname)
            lines.append(f"# TYPE {pname} {m.kind}")
        if m.kind in ("counter", "gauge"):
            lines.append(f"{pname}{_fmt_labels(m.labels)} {m.value}")
            continue
        acc = 0
        for bound, c in zip(_reg.HIST_BOUNDS, m.buckets):
            acc += c
            lab = _fmt_labels(m.labels + (("le", f"{bound:g}"),))
            lines.append(f"{pname}_bucket{lab} {acc}")
        lab = _fmt_labels(m.labels + (("le", "+Inf"),))
        lines.append(f"{pname}_bucket{lab} {m.count}")
        lines.append(f"{pname}_sum{_fmt_labels(m.labels)} {m.sum:g}")
        lines.append(f"{pname}_count{_fmt_labels(m.labels)} {m.count}")
    return "\n".join(lines) + ("\n" if lines else "")


def _fmt_s(v: float) -> str:
    if v >= 1.0:
        return f"{v:.2f}s"
    if v >= 1e-3:
        return f"{v * 1e3:.2f}ms"
    return f"{v * 1e6:.0f}us"


def console_summary() -> str:
    """Human-readable registry + event roll-up: counters/gauges one per
    line, histograms with count/p50/p90/p99/mean, then event counts."""
    rows = []
    for m in _reg.all_metrics():
        lbl = _fmt_labels(m.labels)
        if m.kind == "counter":
            rows.append((f"{m.name}{lbl}", f"{m.value}"))
        elif m.kind == "gauge":
            rows.append((f"{m.name}{lbl}", f"{m.value:.4g}"))
        else:
            mean = m.sum / m.count if m.count else 0.0
            rows.append((
                f"{m.name}{lbl}",
                f"n={m.count} p50={_fmt_s(m.p50)} p90={_fmt_s(m.p90)} "
                f"p99={_fmt_s(m.p99)} mean={_fmt_s(mean)}"))
    for etype, n in _event_summary().items():
        rows.append((f"event.{etype}", f"{n}"))
    if not rows:
        return "telemetry: no metrics or events recorded\n"
    w = max(len(r[0]) for r in rows)
    head = f"{'metric':<{w}}  value"
    sep = "-" * len(head)
    return "\n".join([head, sep] + [f"{k:<{w}}  {v}" for k, v in rows]) + "\n"
