"""Telemetry subsystem: spans, metrics, device-counter export, events
(DESIGN.md §9).

Zero-cost when off — the default. Every instrumented call site in the
engine, shard dispatch, lifecycle, fault, and serving layers goes through
this surface, and with collection disabled each one reduces to a single
predicate check on the host; nothing obs-related ever enters a jitted
program, so compiled HLO and op outputs are bit-identical either way
(pinned by ``tests/test_obs.py``). Enable with :func:`enable` or
``REPRO_OBS=1``.

Quick tour::

    from repro import obs

    obs.enable()
    with obs.span("descent", shard=0):          # host span + profiler
        vals, rep = lookup_batch(tree, qb, ql)  #   TraceAnnotation
    obs.histogram("serve.request_latency_s").observe(dt)
    obs.counter("shard.retries", op="lookup").inc()
    obs.event("publish", label="compact", version=1, ok=True,
              reason="", duration_s=0.12)
    print(obs.console_summary())
    obs.export_events_jsonl("out/obs/events.jsonl")

Stable public surface — import from here, not from the submodules.
"""
from .bridge import drain_op_report, drain_stats
from .events import (EVENT_TYPES, event, event_summary, events,
                     validate_event)
from .export import console_summary, export_events_jsonl, prometheus_text
from .registry import (HIST_BOUNDS, Counter, Gauge, Histogram, all_metrics,
                       counter, disable, enable, enabled, gauge, get_metric,
                       histogram, reset)
from .trace import current_path, span

__all__ = [
    # state
    "enabled", "enable", "disable", "reset",
    # spans
    "span", "current_path",
    # metrics
    "Counter", "Gauge", "Histogram", "HIST_BOUNDS",
    "counter", "gauge", "histogram", "get_metric", "all_metrics",
    # device-counter bridge
    "drain_stats", "drain_op_report",
    # events
    "EVENT_TYPES", "event", "events", "event_summary", "validate_event",
    # exporters
    "export_events_jsonl", "prometheus_text", "console_summary",
]
