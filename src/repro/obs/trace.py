"""Nestable host-side tracing spans (DESIGN.md §9).

``span("descent", shard=0)`` times a host-side region into the metrics
registry (histogram ``span.<dotted.path>``, the path being the names of
the enclosing spans joined with dots, so the same leaf name nested under
different parents stays distinguishable) and, when the JAX profiler is
capturing, emits a ``jax.profiler.TraceAnnotation`` so the host region
lines up with the device timeline in the trace viewer.

While telemetry is off, ``span`` hands back a shared null context manager
— one predicate check per call site, nothing recorded, and never anything
inside a jitted program (spans wrap launches; they are invisible to
tracing, which is what keeps compiled HLO byte-identical either way).
"""
from __future__ import annotations

import time
from typing import List, Optional

from . import registry as _reg

__all__ = ["span", "current_path"]

_STACK: List[str] = []


def current_path() -> str:
    """Dotted path of the innermost open span ("" at top level)."""
    return ".".join(_STACK)


class _NullSpan:
    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


_NULL = _NullSpan()


class _Span:
    __slots__ = ("name", "labels", "path", "t0", "_annot")

    def __init__(self, name: str, labels: dict):
        self.name = name
        self.labels = labels
        self.path = ""
        self.t0 = 0.0
        self._annot = None

    def __enter__(self):
        _STACK.append(self.name)
        self.path = ".".join(_STACK)
        try:                       # device-timeline alignment is best-effort
            import jax.profiler
            self._annot = jax.profiler.TraceAnnotation(self.path)
            self._annot.__enter__()
        except Exception:
            self._annot = None
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *exc):
        dt = time.perf_counter() - self.t0
        if self._annot is not None:
            try:
                self._annot.__exit__(*exc)
            except Exception:
                pass
        if _STACK and _STACK[-1] == self.name:
            _STACK.pop()
        _reg.histogram(f"span.{self.path}", **self.labels).observe(dt)
        return False


def span(name: str, **labels):
    """Context manager timing a host region into histogram
    ``span.<path>`` (labels become metric labels — keep their cardinality
    bounded: shard ids and op names, not batch contents)."""
    if not _reg.enabled():
        return _NULL
    return _Span(name, labels)
