"""Device-counter export: drain stats pytrees into the registry.

The tree's modeled hardware counters (``BranchStats``/``LeafStats``, and
the op-level ``OpReport``/``BuildReport`` aggregates built from them) are
device arrays produced by the jitted ops — under a stats-free engine the
whole machinery compiles away (DESIGN.md §3) and there is nothing to
drain. This bridge is the host-side sink for the stats-on path: ONE
``jax.device_get`` per batch pulls the entire pytree across (never a
per-level or per-field sync), then per-lane counters are summed into
registry counters named ``tree.<field>`` labeled by op.

Draining preserves the compile-away contract by construction: it only
touches values the op already returned, so enabling telemetry changes no
traced program — the A/B in ``tests/test_obs.py`` pins that the drained
totals match the ``BranchStats`` sums ``tests/test_traverse_parity.py``
asserts directly.
"""
from __future__ import annotations

from typing import Optional

from . import registry as _reg

__all__ = ["drain_stats", "drain_op_report"]

# OpReport counter columns that come from BranchStats/LeafStats
# (DESIGN.md §3); `found` et al. are outcomes, not device counters.
_REPORT_COUNTERS = ("feat_rounds", "suffix_bs", "key_compares",
                    "lines_touched", "tag_candidates")


def _host(pytree):
    import jax
    return jax.device_get(pytree)


def drain_stats(stats, prefix: str = "tree", **labels) -> None:
    """Drain one stats NamedTuple (``BranchStats``/``LeafStats``) into
    counters ``<prefix>.<field>``. ``stats=None`` (stats-free engine) is a
    no-op, as is a disabled registry."""
    if not _reg.enabled() or stats is None:
        return
    host = _host(stats)                        # one device->host sync
    for f, col in zip(stats._fields, host):
        _reg.counter(f"{prefix}.{f}", **labels).inc(int(col.sum()))


def drain_op_report(op: str, rep, batch: Optional[int] = None) -> None:
    """Drain a ``core.batch_ops.OpReport`` after one batched op: the
    BranchStats/LeafStats-derived per-lane counters, plus op-level
    ``op.calls`` / ``op.lanes`` / ``op.found`` / ``op.conflicts`` /
    ``op.splits`` outcomes, all labeled ``op=<name>``."""
    if not _reg.enabled() or rep is None:
        return
    host = _host(rep)                          # one device->host sync
    d = dict(zip(rep._fields, host))
    _reg.counter("op.calls", op=op).inc()
    found = d.get("found")
    if found is not None:
        _reg.counter("op.lanes", op=op).inc(int(found.size))
        _reg.counter("op.found", op=op).inc(int(found.sum()))
    for f in ("conflicts", "splits"):
        if f in d:
            _reg.counter(f"op.{f}", op=op).inc(int(d[f]))
    for f in _REPORT_COUNTERS:
        if f in d:
            _reg.counter(f"tree.{f}", op=op).inc(int(d[f].sum()))
