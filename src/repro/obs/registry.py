"""Process-global metrics registry: counters, gauges, latency histograms.

The registry is the host-side half of the telemetry subsystem
(DESIGN.md §9). Everything hangs off one module-global ``enabled`` flag:

* **off** (the default) — ``counter()``/``gauge()``/``histogram()`` return
  a shared no-op metric and :func:`span`/:func:`event` short-circuit, so
  instrumented call sites cost one predicate check. Nothing obs-related is
  ever traced into a jitted program either way — instrumentation lives at
  the host call sites around jitted launches, which is what keeps the
  zero-cost contract bit-exact (same HLO, same outputs) rather than merely
  cheap.
* **on** — metrics are created on first touch, keyed by
  ``(name, sorted labels)``, and accumulate until :func:`reset`.

Histograms use fixed log2 buckets (1 µs … ~1.2 h for latencies, but any
positive value works): ``observe`` is one ``bisect`` per sample, quantile
readout walks the cumulative counts and interpolates geometrically inside
the winning bucket — good to a factor of ``2**0.5`` worst case, which is
plenty for p50/p90/p99 latency reporting and costs no per-sample storage.

Single-threaded by design, like the dispatch loops it instruments; the
registry is plain dicts with no locking.
"""
from __future__ import annotations

import math
import os
from bisect import bisect_left
from typing import Dict, List, Optional, Tuple

__all__ = [
    "Counter", "Gauge", "Histogram", "HIST_BOUNDS",
    "enabled", "enable", "disable", "reset",
    "counter", "gauge", "histogram", "all_metrics", "get_metric",
]

# ---------------------------------------------------------------- state

_ENABLED = os.environ.get("REPRO_OBS", "") not in ("", "0")

# (name, ((label, value), ...)) -> metric
_METRICS: Dict[Tuple[str, Tuple[Tuple[str, str], ...]], object] = {}


def enabled() -> bool:
    """Is telemetry collection on? Instrumented call sites check this
    once and fall through to the uninstrumented path when off."""
    return _ENABLED


def enable() -> None:
    global _ENABLED
    _ENABLED = True


def disable() -> None:
    """Turn collection off. Existing metrics are kept (readable/exportable)
    until :func:`reset`; new samples are dropped."""
    global _ENABLED
    _ENABLED = False


def reset() -> None:
    """Clear every metric and the event log (per-test isolation). The
    enabled flag is left as-is."""
    # import the submodule explicitly: the package re-exports an `events()`
    # *function* that shadows the module attribute of the same name
    from .events import _clear
    _METRICS.clear()
    _clear()


def _labelkey(labels: dict) -> Tuple[Tuple[str, str], ...]:
    return tuple(sorted((k, str(v)) for k, v in labels.items()))


# -------------------------------------------------------------- metrics

class Counter:
    """Monotonic counter."""

    kind = "counter"

    def __init__(self, name: str, labels: Tuple[Tuple[str, str], ...] = ()):
        self.name = name
        self.labels = labels
        self.value = 0

    def inc(self, n: int = 1) -> None:
        self.value += int(n)


class Gauge:
    """Last-write-wins instantaneous value."""

    kind = "gauge"

    def __init__(self, name: str, labels: Tuple[Tuple[str, str], ...] = ()):
        self.name = name
        self.labels = labels
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = float(v)


# log2 buckets: 1 µs, 2 µs, 4 µs, ... ~1.2 h (upper bounds, seconds).
# Shared by every histogram so quantiles are comparable across metrics
# and the Prometheus export emits one consistent ``le`` ladder.
HIST_BOUNDS: Tuple[float, ...] = tuple(1e-6 * 2 ** i for i in range(33))


class Histogram:
    """Fixed-bucket log-scale histogram with quantile readout."""

    kind = "histogram"

    def __init__(self, name: str, labels: Tuple[Tuple[str, str], ...] = ()):
        self.name = name
        self.labels = labels
        self.buckets = [0] * (len(HIST_BOUNDS) + 1)   # +1 overflow bucket
        self.count = 0
        self.sum = 0.0

    def observe(self, v: float) -> None:
        v = float(v)
        self.buckets[bisect_left(HIST_BOUNDS, v)] += 1
        self.count += 1
        self.sum += v

    def percentile(self, q: float) -> float:
        """Approximate q-quantile (q in [0, 1]): geometric midpoint of the
        bucket holding the q-th sample; 0.0 on an empty histogram."""
        if self.count == 0:
            return 0.0
        target = max(1, math.ceil(q * self.count))
        acc = 0
        for i, c in enumerate(self.buckets):
            acc += c
            if acc >= target:
                if i >= len(HIST_BOUNDS):          # overflow bucket
                    return HIST_BOUNDS[-1]
                hi = HIST_BOUNDS[i]
                lo = HIST_BOUNDS[i - 1] if i > 0 else hi / 2.0
                return math.sqrt(lo * hi)
        return HIST_BOUNDS[-1]

    @property
    def p50(self) -> float:
        return self.percentile(0.50)

    @property
    def p90(self) -> float:
        return self.percentile(0.90)

    @property
    def p99(self) -> float:
        return self.percentile(0.99)


class _NullMetric:
    """Shared do-nothing metric handed out while telemetry is off, so call
    sites never branch on the flag themselves."""

    __slots__ = ()

    def inc(self, n: int = 1) -> None:
        pass

    def set(self, v: float) -> None:
        pass

    def observe(self, v: float) -> None:
        pass


_NULL = _NullMetric()


def _get(cls, name: str, labels: dict):
    key = (name, _labelkey(labels))
    m = _METRICS.get(key)
    if m is None:
        m = _METRICS[key] = cls(name, key[1])
    elif not isinstance(m, cls):
        raise TypeError(f"metric {name!r} already registered as {m.kind}")
    return m


def counter(name: str, **labels) -> Counter:
    """Get-or-create a counter (no-op metric while disabled)."""
    return _get(Counter, name, labels) if _ENABLED else _NULL


def gauge(name: str, **labels) -> Gauge:
    return _get(Gauge, name, labels) if _ENABLED else _NULL


def histogram(name: str, **labels) -> Histogram:
    return _get(Histogram, name, labels) if _ENABLED else _NULL


def get_metric(name: str, **labels):
    """Read-side lookup: the metric, or None if never touched. Works with
    collection disabled (post-run assertions / exporters)."""
    return _METRICS.get((name, _labelkey(labels)))


def all_metrics() -> List[object]:
    """Every registered metric, sorted by (name, labels) for stable
    export order."""
    return [_METRICS[k] for k in sorted(_METRICS)]
