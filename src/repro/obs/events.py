"""Structured telemetry event log (DESIGN.md §9).

Events are the discrete, low-rate facts the metrics registry can't carry:
a lifecycle publish committed or aborted, a fault fired, a shard retry /
degraded serve / failed-lane batch, a rebalance recovery. Each event is a
flat dict — ``type`` + ``seq`` + ``ts`` plus the type's required fields —
append-only in arrival order, exported as JSON lines
(``repro.obs.export``) and schema-checked in CI
(``tools/check_obs_export.py``).

The type table below is the single source of truth for that schema:
:func:`event` refuses unknown types and missing required fields at emit
time (an instrumentation bug should fail the emitting test, not produce
an unparseable artifact), and the CI checker imports the same table so
the exporter and the validator can never drift apart.
"""
from __future__ import annotations

import time
from typing import Dict, List, Optional, Tuple

from . import registry as _reg

__all__ = ["EVENT_TYPES", "event", "events", "event_summary",
           "validate_event"]

# type -> required field names (beyond the envelope's type/seq/ts).
# Optional fields are free-form; validation only pins the required set.
EVENT_TYPES: Dict[str, Tuple[str, ...]] = {
    # lifecycle (core.lifecycle): one per publish attempt, ok or not
    "publish":        ("label", "version", "ok", "reason", "duration_s"),
    # fsck gate rejected a staged tree (also reflected in its publish event)
    "fsck":           ("label", "violations"),
    # fault injection (core.faults): one per fired fault, replay context
    "fault":          ("site", "kind", "seed"),
    # shard dispatch (shard.ops)
    "shard.retry":    ("op", "shard", "attempt"),
    "shard.down":     ("op", "shard", "attempts"),
    "shard.degraded": ("op", "shard", "lanes"),
    "shard.failed":   ("op", "shard", "lanes"),
    # recovery barrier (shard.ops.rebalance)
    "rebalance":      ("n_live", "reclaimed"),
}

_EVENTS: List[dict] = []
_SEQ = 0


def _clear() -> None:
    global _SEQ
    _EVENTS.clear()
    _SEQ = 0


def _jsonable(v):
    """Coerce numpy scalars / tuples so every event dumps with the stock
    json encoder."""
    if isinstance(v, (list, tuple)):
        return [_jsonable(x) for x in v]
    if isinstance(v, (str, int, float, bool)) or v is None:
        return v
    if hasattr(v, "item"):          # numpy / jax scalar
        return v.item()
    return str(v)


def event(etype: str, **fields) -> Optional[dict]:
    """Record one structured event (no-op while telemetry is off).

    Unknown ``etype`` or missing required fields raise immediately —
    the emit-time schema gate that keeps exports machine-checkable.
    Returns the recorded dict (None when disabled).
    """
    if not _reg.enabled():
        return None
    required = EVENT_TYPES.get(etype)
    if required is None:
        raise ValueError(f"unknown telemetry event type {etype!r}; "
                         f"one of {sorted(EVENT_TYPES)}")
    missing = [f for f in required if f not in fields]
    if missing:
        raise ValueError(f"event {etype!r} missing required fields "
                         f"{missing}; requires {list(required)}")
    global _SEQ
    e = {"type": etype, "seq": _SEQ, "ts": time.time()}
    e.update({k: _jsonable(v) for k, v in fields.items()})
    _EVENTS.append(e)
    _SEQ += 1
    return e


def events() -> List[dict]:
    """The event log so far, in emit order (live list — don't mutate)."""
    return _EVENTS


def event_summary() -> Dict[str, int]:
    """``{type: count}`` over the log — the console one-liner chaos
    failures print next to the replay seed."""
    out: Dict[str, int] = {}
    for e in _EVENTS:
        out[e["type"]] = out.get(e["type"], 0) + 1
    return dict(sorted(out.items()))


def validate_event(e: object) -> List[str]:
    """Schema-check one decoded JSON-lines record; returns the list of
    violations (empty = valid). Shared by ``tools/check_obs_export.py``."""
    errs = []
    if not isinstance(e, dict):
        return [f"event is {type(e).__name__}, expected object"]
    etype = e.get("type")
    if etype not in EVENT_TYPES:
        return [f"unknown event type {etype!r}"]
    for f in ("seq", "ts"):
        if not isinstance(e.get(f), (int, float)):
            errs.append(f"{etype}: field {f!r} missing or non-numeric")
    for f in EVENT_TYPES[etype]:
        if f not in e:
            errs.append(f"{etype}: missing required field {f!r}")
    return errs
