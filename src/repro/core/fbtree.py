"""FB+-tree core structure (structure-of-arrays, JAX pytree).

Layout mirrors the paper's node structures (Fig. 5) adapted to a pointer-free
structure-of-arrays device representation (DESIGN.md §1):

* inner level ``l`` (level 0 = root, fixed height — upper levels may be
  single-child chains so the compiled traversal is shape-static):
  - ``knum``      number of anchors (== number of children)
  - ``plen``      common-prefix length of the node's anchors
  - ``prefix``    embedded common prefix bytes (the ``tiny``/``huge`` fields)
  - ``features``  ``uint8[fs, ns]`` — byte ``plen+fid`` of every anchor,
    transposed so one row is one SIMD vector (paper §3.3)
  - ``children``  child ids (next level / leaf ids)
  - ``anchors``   key ids (pointers to high keys — the paper stores pointers,
    not key copies; here: indices into the key pool)
* leaves: unsorted kv slots + occupancy bitmap + 1-byte hashtags + high key +
  sibling link + version word (insert/remove bump it; updates do *not* — §4.2).

Anchor convention: ``anchors[i]`` is the minimum key of ``children[i]``'s
subtree; child ``i`` covers ``[anchors[i], anchors[i+1])`` and keys below
``anchors[0]`` descend to child 0.

Construction comes in two parity-locked flavors (DESIGN.md §5):
:func:`bulk_build` is the host numpy reference; ``bulk_build(device=True)``
runs the same algorithm as a jit-compatible jnp pipeline
(:func:`_device_build_from_sorted`) whose only Python loop is over the
O(log n) tree height. Both produce bit-identical ``TreeArrays``; the device
core is also what ``core.batch_ops.rebuild`` re-invokes in-graph to compact
a split-fragmented live tree.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, List, NamedTuple, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import keys as K

__all__ = ["TreeConfig", "Level", "FBTree", "bulk_build", "tree_to_device",
           "stack_levels", "chunk_start", "chunk_of_pos",
           "recompute_inner_meta", "sharded_partition"]

EMPTY = np.int32(-1)
BIG = jnp.int32(2**30)


@dataclasses.dataclass(frozen=True)
class TreeConfig:
    """Static tree geometry (hashable: rides through ``jax.jit`` as aux data).

    Every array in :class:`TreeArrays` has a shape fully determined by this
    config, so one config == one compiled specialization of every batched op.

    Fields:

    * ``key_width``   fixed key-pool row width ``L`` in bytes; keys are
      zero-padded to it (order preserved via the length tie-break,
      ``core.keys``).
    * ``ns``          slots per leaf == anchors per inner node (paper
      default 64).
    * ``fs``          feature bytes per anchor (paper default 4).
    * ``leaf_fill`` / ``inner_fill``  bulk-load & repack target occupancy;
      builds chunk sorted runs into ``ceil(n / fill)`` balanced nodes.
    * ``n_levels``    fixed inner height including root chain. Trees smaller
      than the capacity plan keep the same height via single-child chain
      nodes at the top (free pass-throughs, never billed in stats).
    * ``leaf_cap`` / ``level_caps`` / ``key_cap``  allocation watermark caps;
      arrays are padded to ``cap + 1`` rows, the extra row being the scratch
      row masked scatters dump into (DESIGN.md §1).
    * ``val_dtype``   leaf value dtype.
    * ``stacked``     default descent layout for the traversal engine:
      False = per-level tuple (Python loop), True = stacked
      ``[n_levels, C_max, ...]`` arrays driven by one ``lax.scan``. Both
      layouts are always materialized and kept coherent.
    """
    key_width: int
    ns: int = 64           # slots / anchors per node (paper default 64)
    fs: int = 4            # feature bytes per anchor (paper default 4)
    leaf_fill: int = 48    # bulk-load / repack target occupancy
    inner_fill: int = 48
    n_levels: int = 3      # inner levels incl. root chain
    leaf_cap: int = 1024
    level_caps: Tuple[int, ...] = (1, 16, 256)
    key_cap: int = 65536
    val_dtype: Any = jnp.int32
    # default descent layout for the traversal engine: False = per-level
    # tuple (Python loop), True = stacked [n_levels, C_max, ...] arrays
    # driven by one lax.scan. Both layouts are always materialized.
    stacked: bool = False

    def __post_init__(self):
        # fail at construction with an actionable message instead of a
        # shape explosion (or a silent mis-build) in the first jitted op
        def bad(msg: str):
            raise ValueError(f"TreeConfig: {msg}")
        if self.key_width < 1:
            bad(f"key_width must be >= 1, got {self.key_width} (bytes per "
                f"fixed-width key-pool row)")
        if self.ns < 2:
            bad(f"ns must be >= 2, got {self.ns} — a node needs at least "
                f"two slots to ever split")
        if self.fs < 1:
            bad(f"fs must be >= 1, got {self.fs} (feature bytes per "
                f"anchor)")
        if not (1 <= self.leaf_fill <= self.ns):
            bad(f"leaf_fill must be in [1, ns={self.ns}], got "
                f"{self.leaf_fill} — TreeConfig.plan clamps it for you")
        if not (1 <= self.inner_fill <= self.ns):
            bad(f"inner_fill must be in [1, ns={self.ns}], got "
                f"{self.inner_fill} — TreeConfig.plan clamps it for you")
        if self.n_levels < 1:
            bad(f"n_levels must be >= 1, got {self.n_levels}")
        if len(self.level_caps) != self.n_levels:
            bad(f"level_caps has {len(self.level_caps)} entries for "
                f"n_levels={self.n_levels} — one cap per inner level, "
                f"root first (TreeConfig.plan derives them)")
        if any(c < 1 for c in self.level_caps):
            bad(f"level_caps must all be >= 1, got {self.level_caps}")
        if self.leaf_cap < 1:
            bad(f"leaf_cap must be >= 1, got {self.leaf_cap}")
        if self.key_cap < 1:
            bad(f"key_cap must be >= 1, got {self.key_cap}")

    @staticmethod
    def plan(max_keys: int, key_width: int, ns: int = 64, fs: int = 4,
             leaf_fill: int = 48, inner_fill: int = 48,
             val_dtype: Any = jnp.int32, stacked: bool = False) -> "TreeConfig":
        """Capacity planning: fixed height with min-fanout-16 safety margin.

        Guarantees that any key set up to ``max_keys`` (and any tree holding
        at most that many live keys, e.g. after a ``rebuild``) fits the caps:
        ``leaf_cap = ceil(max_keys / max(8, leaf_fill // 3))`` and each inner
        level cap is ``ceil(child_cap / 16)`` up to a single-node root.
        """
        leaf_cap = max(2, -(-max_keys // max(8, leaf_fill // 3)))
        caps: List[int] = []
        c = leaf_cap
        while True:
            c = max(1, -(-c // 16))
            caps.append(c)
            if c == 1:
                break
        caps = caps[::-1]  # root first
        return TreeConfig(key_width=key_width, ns=ns, fs=fs,
                          leaf_fill=min(leaf_fill, ns), inner_fill=min(inner_fill, ns),
                          n_levels=len(caps), leaf_cap=leaf_cap,
                          level_caps=tuple(caps), key_cap=int(max_keys),
                          val_dtype=val_dtype, stacked=stacked)


class Level(NamedTuple):
    """One inner level, ``C = level_caps[l] + 1`` rows (last row = scratch).

    Rows past ``count`` are zeroed pads (``knum=0``,
    ``children=anchors=EMPTY``) that every backend treats as trivial nodes.
    In the stacked layout (:func:`stack_levels`) the same six arrays gain a
    leading ``n_levels`` axis and ``count`` becomes an ``int32[n_levels]``
    vector.
    """
    knum: jnp.ndarray      # int32 [C]
    plen: jnp.ndarray      # int32 [C]
    prefix: jnp.ndarray    # uint8 [C, L]
    features: jnp.ndarray  # uint8 [C, fs, ns]
    children: jnp.ndarray  # int32 [C, ns]
    anchors: jnp.ndarray   # int32 [C, ns]  (key ids)
    count: jnp.ndarray     # int32 scalar — allocation watermark


class TreeArrays(NamedTuple):
    """All tree state as a flat pytree of device arrays.

    Shapes below use ``KC = key_cap + 1``, ``LC = leaf_cap + 1`` (the ``+1``
    is the scratch row, DESIGN.md §1), ``L = key_width``, ``ns`` slots.

    Invariants the parity/property suites check
    (``tests/test_traverse_parity.py``, ``tests/test_tree_ops.py``):

    * key-pool rows ``[0, key_count)`` hold valid keys; rows at or above the
      watermark (and the scratch row) are zero.
    * ``levels`` and ``stacked`` describe the same tree: re-deriving
      ``stacked`` via :func:`stack_levels` is a no-op, and every
      backend × layout combination descends to identical leaves with
      identical machine-independent stats (DESIGN.md §3).
    * each live key id appears in exactly one occupied leaf slot;
      ``leaf_high``/``leaf_next`` order leaves ascending with the last
      leaf's high key ``EMPTY`` (= +inf).
    * ``leaf_version`` bumps on insert/remove but never on update
      (paper §4.2); a fresh build resets versions to zero (DESIGN.md §5).
    """
    key_bytes: jnp.ndarray   # uint8 [KC, L]
    key_lens: jnp.ndarray    # int32 [KC]
    key_tags: jnp.ndarray    # uint8 [KC] hash fingerprints (computed at append)
    key_count: jnp.ndarray   # int32 scalar
    levels: Tuple[Level, ...]
    stacked: Level           # same levels, stacked+padded to [n_levels, C_max, ...]
    leaf_tags: jnp.ndarray   # uint8 [LC, ns]
    leaf_keyid: jnp.ndarray  # int32 [LC, ns] (-1 empty)
    leaf_val: jnp.ndarray    # val_dtype [LC, ns]
    leaf_occ: jnp.ndarray    # bool [LC, ns]
    leaf_high: jnp.ndarray   # int32 [LC] key id, -1 = +inf
    leaf_next: jnp.ndarray   # int32 [LC]
    leaf_version: jnp.ndarray  # int32 [LC]
    leaf_ordered: jnp.ndarray  # bool [LC]
    leaf_count: jnp.ndarray    # int32 scalar


@jax.tree_util.register_pytree_node_class
class FBTree:
    """Pytree wrapper: arrays are leaves, config is static aux data."""

    def __init__(self, config: TreeConfig, arrays: TreeArrays):
        self.config = config
        self.arrays = arrays

    def tree_flatten(self):
        return (self.arrays,), self.config

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(aux, children[0])

    # convenience accessors
    def __getattr__(self, name):
        if name in TreeArrays._fields:
            return getattr(self.arrays, name)
        raise AttributeError(name)

    def replace(self, **kw) -> "FBTree":
        return FBTree(self.config, self.arrays._replace(**kw))

    @property
    def n_keys_live(self) -> int:
        return int(jnp.sum(self.arrays.leaf_occ))


def stack_levels(levels: Tuple[Level, ...]) -> Level:
    """Stack per-level arrays into one padded [n_levels, C_max, ...] Level.

    Rows past a level's own cap are knum=0 / children=anchors=EMPTY, so a
    backend treats them as trivial nodes (well-formed descents never land on
    them). ``count`` becomes an int32 [n_levels] vector. Pure jnp: callable
    under jit, so mutating ops can refresh the stacked copy in-graph. This is
    the level-synchronous layout the ``lax.scan`` descent consumes
    (DESIGN.md §3); both builders materialize it alongside ``levels``.
    """
    C_max = max(l.knum.shape[0] for l in levels)

    def pad(a, fillv):
        short = C_max - a.shape[0]
        if short == 0:
            return a
        return jnp.concatenate(
            [a, jnp.full((short,) + a.shape[1:], fillv, a.dtype)], axis=0)

    return Level(
        knum=jnp.stack([pad(l.knum, 0) for l in levels]),
        plen=jnp.stack([pad(l.plen, 0) for l in levels]),
        prefix=jnp.stack([pad(l.prefix, 0) for l in levels]),
        features=jnp.stack([pad(l.features, 0) for l in levels]),
        children=jnp.stack([pad(l.children, EMPTY) for l in levels]),
        anchors=jnp.stack([pad(l.anchors, EMPTY) for l in levels]),
        count=jnp.stack([l.count for l in levels]),
    )


# --------------------------------------------------------------------------
# shared segmented-construction primitives (host build, device build, and the
# batch_ops split path all use these — DESIGN.md §5)
# --------------------------------------------------------------------------

def chunk_of_pos(p, base, rem):
    """Chunk index of position ``p`` under balanced chunking.

    ``n`` items over ``c`` chunks with ``base = n // c``, ``rem = n % c``:
    the first ``rem`` chunks hold ``base + 1`` items, the rest ``base``.
    """
    cut = (base + 1) * rem
    return jnp.where(p < cut, p // jnp.maximum(base + 1, 1),
                     rem + (p - cut) // jnp.maximum(base, 1)).astype(jnp.int32)


def chunk_start(c, base, rem):
    """First item position of chunk ``c`` (inverse of :func:`chunk_of_pos`)."""
    return jnp.where(c <= rem, c * (base + 1),
                     rem * (base + 1) + (c - rem) * base).astype(jnp.int32)


def recompute_inner_meta(kb_store, kl_store, anchors, knum, fs):
    """Segmented reduction deriving ``plen``/``prefix``/``features`` for a
    block of inner nodes from their anchor key ids. ``anchors`` is ``[R, ns]``
    with ``EMPTY`` pads; invalid lanes contribute the identity.

    The common-prefix length is the first byte column where some valid anchor
    differs from anchor 0, clipped by the shortest anchor length and the key
    width; feature row ``f`` is byte ``plen + f`` of every anchor (0 when past
    the key width). Shared verbatim by the device build and the insert split
    path so split-produced and built nodes agree byte-for-byte.
    """
    R, ns = anchors.shape
    L = kb_store.shape[-1]
    aid = jnp.maximum(anchors, 0)
    akb = kb_store[aid]                       # [R, ns, L]
    akl = kl_store[aid]
    lane = jnp.arange(ns, dtype=jnp.int32)[None, :]
    valid = lane < knum[:, None]
    first = akb[:, :1, :]
    same = (akb == first) | ~valid[:, :, None]
    allsame = same.all(axis=1)                # [R, L]
    plen = jnp.where(allsame.all(-1), L,
                     jnp.argmin(allsame.astype(jnp.int32), axis=-1))
    minlen = jnp.min(jnp.where(valid, akl, BIG), axis=-1)
    plen = jnp.minimum(plen, jnp.minimum(minlen, L)).astype(jnp.int32)
    prefix = akb[:, 0, :]
    feats = []
    for f in range(fs):
        pos = jnp.clip(plen + f, 0, L - 1)        # [R]
        byte = jnp.take_along_axis(
            akb, jnp.broadcast_to(pos[:, None, None], (R, ns, 1)), axis=-1)[..., 0]
        byte = jnp.where(((plen + f)[:, None] < L) & valid, byte, 0)
        feats.append(byte.astype(jnp.uint8))
    features = jnp.stack(feats, axis=1)       # [R, fs, ns]
    return plen, prefix, features


# --------------------------------------------------------------------------
# host (numpy) build — the parity reference
# --------------------------------------------------------------------------

def _common_prefix_len(kb: np.ndarray, kl: np.ndarray) -> Tuple[int, np.ndarray]:
    """plen + prefix bytes over rows of a [k, L] anchor byte block."""
    L = kb.shape[1]
    if kb.shape[0] == 1:
        pl = int(min(kl[0], L))
        return pl, kb[0]
    eq = (kb == kb[:1]).all(axis=0)           # [L]
    neq = np.nonzero(~eq)[0]
    pl = int(neq[0]) if neq.size else L
    pl = int(min(pl, kl.min()))
    return pl, kb[0]


def _build_inner_level_np(cfg: TreeConfig, child_min_keyid: np.ndarray,
                          key_bytes: np.ndarray, key_lens: np.ndarray,
                          fill: int) -> Tuple[dict, np.ndarray]:
    """Group children into inner nodes; return level arrays + per-node min key id."""
    ns, fs, L = cfg.ns, cfg.fs, cfg.key_width
    n_child = child_min_keyid.shape[0]
    n_nodes = max(1, -(-n_child // fill))
    # balanced grouping
    base = n_child // n_nodes
    rem = n_child % n_nodes
    sizes = np.full(n_nodes, base, dtype=np.int64)
    sizes[:rem] += 1
    starts = np.concatenate([[0], np.cumsum(sizes)[:-1]])

    knum = np.zeros(n_nodes, dtype=np.int32)
    plen = np.zeros(n_nodes, dtype=np.int32)
    prefix = np.zeros((n_nodes, L), dtype=np.uint8)
    features = np.zeros((n_nodes, fs, ns), dtype=np.uint8)
    children = np.full((n_nodes, ns), EMPTY, dtype=np.int32)
    anchors = np.full((n_nodes, ns), EMPTY, dtype=np.int32)
    node_min = np.zeros(n_nodes, dtype=np.int32)

    for i in range(n_nodes):
        s, k = int(starts[i]), int(sizes[i])
        ids = child_min_keyid[s:s + k]
        kb = key_bytes[ids]
        kl = key_lens[ids]
        pl, pfx = _common_prefix_len(kb, kl)
        knum[i] = k
        plen[i] = pl
        prefix[i] = pfx
        for f in range(fs):
            pos = pl + f
            if pos < L:
                features[i, f, :k] = kb[:, pos]
        children[i, :k] = np.arange(s, s + k, dtype=np.int32)
        anchors[i, :k] = ids
        node_min[i] = ids[0]
    return dict(knum=knum, plen=plen, prefix=prefix, features=features,
                children=children, anchors=anchors, count=np.int32(n_nodes)), node_min


def _check_capacity(cfg: TreeConfig, n: int) -> None:
    """Host-side mirror of the device build's capacity checks."""
    assert n <= cfg.key_cap, "key_cap exceeded"
    assert cfg.leaf_fill <= cfg.ns and cfg.inner_fill <= cfg.ns, \
        "fill targets cannot exceed ns slots (TreeConfig.plan clamps them)"
    c = max(1, -(-n // cfg.leaf_fill))
    assert c <= cfg.leaf_cap, "leaf_cap exceeded"
    for lvl in range(cfg.n_levels - 1, -1, -1):
        c = max(1, -(-c // cfg.inner_fill))
        assert c <= cfg.level_caps[lvl], f"level {lvl}: {c} > cap"
    assert c == 1, "tree too shallow for n_levels — use TreeConfig.plan"


def bulk_build(cfg: TreeConfig, ks: K.KeySet, vals: np.ndarray,
               device: bool = False) -> FBTree:
    """Bulk-load a tree from (possibly unsorted) unique keys.

    ``device=False`` (default) runs the numpy host reference: sort on host,
    chunk the sorted run into balanced leaves, then group bottom-up into
    inner levels, padding to the fixed height with single-child chain nodes.

    ``device=True`` runs the jit-compatible device pipeline (DESIGN.md §5):
    sort via packed-word ``jnp.lexsort``, build leaves and every inner level
    with segmented jnp reductions (:func:`recompute_inner_meta`), the only
    Python loop being over the O(log n) height. Both paths produce
    bit-identical :class:`TreeArrays` (including the stacked layout) — the
    equivalence tests in ``tests/test_tree_ops.py`` pin this contract.

    Shapes: ``ks.bytes`` is ``uint8 [n, key_width]``, ``ks.lens`` ``int32
    [n]``, ``vals`` ``[n]`` (cast to ``cfg.val_dtype``). Raises on capacity
    overflow (``key_cap`` / ``leaf_cap`` / ``level_caps``).
    """
    ns, fs, L = cfg.ns, cfg.fs, cfg.key_width
    n = ks.n
    _check_capacity(cfg, n)
    if device:
        return _bulk_build_device(cfg, ks, vals)
    order = K.lex_sort_indices(ks)
    # every array gets one trailing scratch row (index cap) so masked scatters
    # have a conflict-free dump target; the watermarks never reach it.
    kb = np.zeros((cfg.key_cap + 1, L), dtype=np.uint8)
    kl = np.zeros((cfg.key_cap + 1,), dtype=np.int32)
    kb[:n] = ks.bytes[order]
    kl[:n] = ks.lens[order]
    vv = np.asarray(vals)[order]

    # ---- leaves ----
    fill = cfg.leaf_fill
    n_leaves = max(1, -(-n // fill))
    assert n_leaves <= cfg.leaf_cap, "leaf_cap exceeded"
    LC = cfg.leaf_cap + 1  # + scratch row
    leaf_tags = np.zeros((LC, ns), dtype=np.uint8)
    leaf_keyid = np.full((LC, ns), EMPTY, dtype=np.int32)
    leaf_val = np.zeros((LC, ns), dtype=np.asarray(vals).dtype)
    leaf_occ = np.zeros((LC, ns), dtype=bool)
    leaf_high = np.full((LC,), EMPTY, dtype=np.int32)
    leaf_next = np.full((LC,), EMPTY, dtype=np.int32)

    tags_all = K.fnv1a_tags(kb[:n], kl[:n])
    base = n // n_leaves
    rem = n % n_leaves
    sizes = np.full(n_leaves, base, dtype=np.int64)
    sizes[:rem] += 1
    starts = np.concatenate([[0], np.cumsum(sizes)[:-1]])
    leaf_min = np.zeros(n_leaves, dtype=np.int32)
    for i in range(n_leaves):
        s, k = int(starts[i]), int(sizes[i])
        leaf_keyid[i, :k] = np.arange(s, s + k, dtype=np.int32)
        leaf_val[i, :k] = vv[s:s + k]
        leaf_tags[i, :k] = tags_all[s:s + k]
        leaf_occ[i, :k] = True
        leaf_min[i] = s
        leaf_next[i] = i + 1 if i + 1 < n_leaves else EMPTY
        leaf_high[i] = s + k if i + 1 < n_leaves else EMPTY

    # ---- inner levels bottom-up ----
    levels_np: List[dict] = []
    child_min = leaf_min
    lvl_arrays, node_min = _build_inner_level_np(cfg, child_min, kb, kl, cfg.inner_fill)
    levels_np.append(lvl_arrays)
    while levels_np[-1]["knum"].shape[0] > 1:
        prev_n = levels_np[-1]["knum"].shape[0]
        lvl_arrays, node_min = _build_inner_level_np(cfg, node_min, kb, kl, cfg.inner_fill)
        levels_np.append(lvl_arrays)
        assert lvl_arrays["knum"].shape[0] < prev_n
    # pad to fixed height with single-child chain roots
    while len(levels_np) < cfg.n_levels:
        ids = node_min[:1]
        pl, pfx = _common_prefix_len(kb[ids], kl[ids])
        feat = np.zeros((1, fs, ns), dtype=np.uint8)
        for f in range(fs):
            if pl + f < L:
                feat[0, f, 0] = kb[ids[0], pl + f]
        levels_np.append(dict(
            knum=np.array([1], np.int32), plen=np.array([pl], np.int32),
            prefix=pfx[None].copy(), features=feat,
            children=np.full((1, ns), EMPTY, np.int32),
            anchors=np.full((1, ns), EMPTY, np.int32),
            count=np.int32(1)))
        levels_np[-1]["children"][0, 0] = 0
        levels_np[-1]["anchors"][0, 0] = ids[0]
    levels_np = levels_np[::-1]  # root first
    assert len(levels_np) == cfg.n_levels, (len(levels_np), cfg.n_levels)

    # pad each level to its cap (+1 scratch row)
    levels: List[Level] = []
    for li, lv in enumerate(levels_np):
        cap = cfg.level_caps[li]
        cur = lv["knum"].shape[0]
        assert cur <= cap, f"level {li}: {cur} > cap {cap}"

        def pad(a, fillv=0):
            out_shape = (cap + 1,) + a.shape[1:]
            out = np.full(out_shape, fillv, dtype=a.dtype)
            out[:cur] = a
            return out

        levels.append(Level(
            knum=jnp.asarray(pad(lv["knum"])),
            plen=jnp.asarray(pad(lv["plen"])),
            prefix=jnp.asarray(pad(lv["prefix"])),
            features=jnp.asarray(pad(lv["features"])),
            children=jnp.asarray(pad(lv["children"], EMPTY)),
            anchors=jnp.asarray(pad(lv["anchors"], EMPTY)),
            count=jnp.asarray(lv["count"]),
        ))

    ktags = np.zeros((cfg.key_cap + 1,), dtype=np.uint8)
    ktags[:n] = tags_all
    arrays = TreeArrays(
        key_bytes=jnp.asarray(kb), key_lens=jnp.asarray(kl),
        key_tags=jnp.asarray(ktags),
        key_count=jnp.asarray(np.int32(n)),
        levels=tuple(levels),
        stacked=stack_levels(tuple(levels)),
        leaf_tags=jnp.asarray(leaf_tags), leaf_keyid=jnp.asarray(leaf_keyid),
        leaf_val=jnp.asarray(leaf_val).astype(cfg.val_dtype),
        leaf_occ=jnp.asarray(leaf_occ),
        leaf_high=jnp.asarray(leaf_high), leaf_next=jnp.asarray(leaf_next),
        leaf_version=jnp.zeros((LC,), jnp.int32),
        leaf_ordered=jnp.asarray(np.arange(LC) < n_leaves),
        leaf_count=jnp.asarray(np.int32(n_leaves)),
    )
    return FBTree(cfg, arrays)


# --------------------------------------------------------------------------
# device (jnp) build — jit-compatible, traced key count (DESIGN.md §5)
# --------------------------------------------------------------------------

def _device_build_from_sorted(cfg: TreeConfig, kb, kl, ktags, vals, n):
    """Construct :class:`TreeArrays` from a sorted, compacted key pool.

    Inputs are pool-shaped (``[key_cap + 1, ...]``) with rows ``[0, n)``
    holding the keys in ascending order and zeros everywhere else; ``n`` may
    be a *traced* int32 (the caller under jit — e.g.
    ``core.batch_ops.rebuild`` — does not know the live count at trace
    time). Returns ``(arrays, error)`` where ``error`` flags a capacity
    overflow (arrays are then shape-valid garbage; callers must discard).

    The pipeline (DESIGN.md §5): balanced chunking of the sorted run into
    leaves via a pure gather grid (no scatter conflicts), then one bottom-up
    pass per inner level — uniform grouping plus
    :func:`recompute_inner_meta` segmented reductions. Grouping a
    single-child run yields exactly the host build's chain-node padding, so
    no special casing is needed for under-full trees and the result is
    bit-identical to the host path.
    """
    ns, fs, L = cfg.ns, cfg.fs, cfg.key_width
    KC = cfg.key_cap
    LC = cfg.leaf_cap + 1
    n = jnp.asarray(n, jnp.int32)
    lane = jnp.arange(ns, dtype=jnp.int32)

    # ---- leaves: balanced chunking of the sorted key run ----
    n_leaves = jnp.maximum(1, -(-n // jnp.int32(cfg.leaf_fill)))
    base, rem = n // n_leaves, n % n_leaves
    li = jnp.arange(LC, dtype=jnp.int32)
    lstart = chunk_start(li, base, rem)            # [LC]
    lsize = base + (li < rem).astype(jnp.int32)
    lexists = li < n_leaves
    pos = lstart[:, None] + lane[None, :]          # key id at (leaf, slot)
    lvalid = lexists[:, None] & (lane[None, :] < lsize[:, None]) & (pos < n)
    pos_safe = jnp.clip(pos, 0, KC)
    leaf_keyid = jnp.where(lvalid, pos, EMPTY)
    leaf_val = jnp.where(lvalid, vals[pos_safe], 0).astype(cfg.val_dtype)
    leaf_tags = jnp.where(lvalid, ktags[pos_safe], 0).astype(jnp.uint8)
    nxt_ok = lexists & (li + 1 < n_leaves)
    leaf_high = jnp.where(nxt_ok, chunk_start(li + 1, base, rem), EMPTY)
    leaf_next = jnp.where(nxt_ok, li + 1, EMPTY)
    # a chunk wider than ns would silently truncate at the lane mask — flag
    # it (host path crashes loudly on the same fill > ns misconfiguration)
    err = (n_leaves > cfg.leaf_cap) | (jnp.where(lexists, lsize, 0) > ns).any()

    # ---- inner levels bottom-up (Python loop over the static height only);
    # grouping a 1-child run reproduces the host chain padding exactly ----
    child_min = jnp.where(lexists, lstart, 0)      # min key id per child
    n_child = n_leaves
    child_cap = LC
    levels_rev: List[Level] = []
    for lvl in range(cfg.n_levels - 1, -1, -1):
        Cn = cfg.level_caps[lvl] + 1
        n_nodes = jnp.maximum(1, -(-n_child // jnp.int32(cfg.inner_fill)))
        nb, nr = n_child // n_nodes, n_child % n_nodes
        ni = jnp.arange(Cn, dtype=jnp.int32)
        nstart = chunk_start(ni, nb, nr)
        nsize = nb + (ni < nr).astype(jnp.int32)
        nexists = ni < n_nodes
        cpos = nstart[:, None] + lane[None, :]     # child id at (node, slot)
        nvalid = (nexists[:, None] & (lane[None, :] < nsize[:, None])
                  & (cpos < n_child))
        cpos_safe = jnp.clip(cpos, 0, child_cap - 1)
        children = jnp.where(nvalid, cpos, EMPTY)
        anchors = jnp.where(nvalid, child_min[cpos_safe], EMPTY)
        knum = jnp.where(nexists, nsize, 0).astype(jnp.int32)
        pl, pf, ft = recompute_inner_meta(kb, kl, anchors, knum, fs)
        levels_rev.append(Level(
            knum=knum,
            plen=jnp.where(nexists, pl, 0).astype(jnp.int32),
            prefix=jnp.where(nexists[:, None], pf, 0).astype(jnp.uint8),
            features=jnp.where(nexists[:, None, None], ft, 0
                               ).astype(jnp.uint8),
            children=children, anchors=anchors,
            count=n_nodes.astype(jnp.int32)))
        err = err | (n_nodes > cfg.level_caps[lvl]) \
            | (jnp.where(nexists, nsize, 0) > ns).any()
        child_min = jnp.where(
            nexists, child_min[jnp.clip(nstart, 0, child_cap - 1)], 0)
        n_child = n_nodes
        child_cap = Cn
    err = err | (n_child != 1)                     # root must be one node
    levels = tuple(levels_rev[::-1])

    arrays = TreeArrays(
        key_bytes=kb, key_lens=kl, key_tags=ktags,
        key_count=n,
        levels=levels,
        stacked=stack_levels(levels),
        leaf_tags=leaf_tags, leaf_keyid=leaf_keyid, leaf_val=leaf_val,
        leaf_occ=lvalid,
        leaf_high=leaf_high, leaf_next=leaf_next,
        leaf_version=jnp.zeros((LC,), jnp.int32),
        leaf_ordered=lexists,
        leaf_count=n_leaves.astype(jnp.int32),
    )
    return arrays, err


_device_build_jit = functools.partial(
    jax.jit, static_argnames=("cfg",))(_device_build_from_sorted)


def _bulk_build_device(cfg: TreeConfig, ks: K.KeySet, vals) -> FBTree:
    """``bulk_build(device=True)`` body: device sort + jitted build core."""
    n, L = ks.n, cfg.key_width
    qb = jnp.asarray(ks.bytes)
    ql = jnp.asarray(ks.lens).astype(jnp.int32)
    order = K.lex_sort_indices_j(qb, ql)
    kb = jnp.zeros((cfg.key_cap + 1, L), jnp.uint8).at[:n].set(qb[order])
    kl = jnp.zeros((cfg.key_cap + 1,), jnp.int32).at[:n].set(ql[order])
    ktags = jnp.zeros((cfg.key_cap + 1,), jnp.uint8).at[:n].set(
        K.fnv1a_tags(qb, ql)[order])
    vv = jnp.zeros((cfg.key_cap + 1,), cfg.val_dtype).at[:n].set(
        jnp.asarray(vals).astype(cfg.val_dtype)[order])
    arrays, err = _device_build_jit(cfg=cfg, kb=kb, kl=kl, ktags=ktags,
                                    vals=vv, n=jnp.int32(n))
    # _check_capacity already vetted n host-side; err re-validates on device
    if bool(err):  # pragma: no cover - unreachable after _check_capacity
        raise RuntimeError("bulk_build(device=True): capacity exceeded")
    return FBTree(cfg, arrays)


def tree_to_device(tree: FBTree) -> FBTree:
    return jax.tree_util.tree_map(jnp.asarray, tree)


# --------------------------------------------------------------------------
# shard-aware build entry (DESIGN.md §7)
# --------------------------------------------------------------------------

def sharded_partition(ks: K.KeySet, vals, n_shards: int,
                      presorted: bool = False):
    """Range-partition a key set for a sharded build: the §5 pipeline's
    step 1 (the global sort) going distributed, with steps 2–3 unchanged
    per shard.

    One global lexicographic sort (``keys.lex_sort_indices`` — the same
    order every build path uses), then a balanced contiguous split into
    ``n_shards`` runs. ``presorted=True`` skips the sort for inputs already
    in that exact order (e.g. ``repro.shard.rebalance``'s concatenation of
    per-shard sorted snapshots — every skew-recovery barrier would
    otherwise pay a redundant O(n log n) host sort). Returns
    ``(parts, split_keys)``:

    * ``parts[s]``      ``(KeySet, vals)`` — shard ``s``'s sorted slice,
      ready for an independent :func:`bulk_build` (host or device);
    * ``split_keys[s]`` ``(bytes_row uint8[L], len)`` — the run's minimum
      key. The shard router replicates these: shard ``s`` owns
      ``[split_keys[s], split_keys[s+1])`` and shard 0 additionally owns
      everything below ``split_keys[0]``.

    Requires ``n >= n_shards`` (an empty shard has no min key to route
    by); shard sizes differ by at most one.
    """
    n = ks.n
    if n_shards < 1:
        raise ValueError(f"sharded_partition: n_shards must be >= 1, "
                         f"got {n_shards}")
    if n < n_shards:
        raise ValueError(
            f"sharded_partition needs at least one key per shard "
            f"(n={n} < n_shards={n_shards}): an empty shard has no "
            f"minimum key for the router — lower n_shards or seed "
            f"sentinel keys")
    if presorted:
        sb, sl, sv = ks.bytes, ks.lens, np.asarray(vals)
    else:
        order = K.lex_sort_indices(ks)
        sb = ks.bytes[order]
        sl = ks.lens[order]
        sv = np.asarray(vals)[order]
    base, rem = divmod(n, n_shards)
    parts = []
    split_keys = []
    start = 0
    for s in range(n_shards):
        k = base + (1 if s < rem else 0)
        parts.append((K.KeySet(sb[start:start + k].copy(),
                               sl[start:start + k].copy()),
                      sv[start:start + k].copy()))
        split_keys.append((sb[start].copy(), int(sl[start])))
        start += k
    return parts, split_keys
