"""FB+-tree core structure (structure-of-arrays, JAX pytree).

Layout mirrors the paper's node structures (Fig. 5) adapted to a pointer-free
structure-of-arrays device representation:

* inner level ``l`` (level 0 = root, fixed height — upper levels may be
  single-child chains so the compiled traversal is shape-static):
  - ``knum``      number of anchors (== number of children)
  - ``plen``      common-prefix length of the node's anchors
  - ``prefix``    embedded common prefix bytes (the ``tiny``/``huge`` fields)
  - ``features``  ``uint8[fs, ns]`` — byte ``plen+fid`` of every anchor,
    transposed so one row is one SIMD vector (paper §3.3)
  - ``children``  child ids (next level / leaf ids)
  - ``anchors``   key ids (pointers to high keys — the paper stores pointers,
    not key copies; here: indices into the key pool)
* leaves: unsorted kv slots + occupancy bitmap + 1-byte hashtags + high key +
  sibling link + version word (insert/remove bump it; updates do *not* — §4.2).

Anchor convention: ``anchors[i]`` is the minimum key of ``children[i]``'s
subtree; child ``i`` covers ``[anchors[i], anchors[i+1])`` and keys below
``anchors[0]`` descend to child 0.
"""
from __future__ import annotations

import dataclasses
from typing import Any, List, NamedTuple, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import keys as K

__all__ = ["TreeConfig", "Level", "FBTree", "bulk_build", "tree_to_device",
           "stack_levels"]

EMPTY = np.int32(-1)


@dataclasses.dataclass(frozen=True)
class TreeConfig:
    key_width: int
    ns: int = 64           # slots / anchors per node (paper default 64)
    fs: int = 4            # feature bytes per anchor (paper default 4)
    leaf_fill: int = 48    # bulk-load / repack target occupancy
    inner_fill: int = 48
    n_levels: int = 3      # inner levels incl. root chain
    leaf_cap: int = 1024
    level_caps: Tuple[int, ...] = (1, 16, 256)
    key_cap: int = 65536
    val_dtype: Any = jnp.int32
    # default descent layout for the traversal engine: False = per-level
    # tuple (Python loop), True = stacked [n_levels, C_max, ...] arrays
    # driven by one lax.scan. Both layouts are always materialized.
    stacked: bool = False

    @staticmethod
    def plan(max_keys: int, key_width: int, ns: int = 64, fs: int = 4,
             leaf_fill: int = 48, inner_fill: int = 48,
             val_dtype: Any = jnp.int32, stacked: bool = False) -> "TreeConfig":
        """Capacity planning: fixed height with min-fanout-16 safety margin."""
        leaf_cap = max(2, -(-max_keys // max(8, leaf_fill // 3)))
        caps: List[int] = []
        c = leaf_cap
        while True:
            c = max(1, -(-c // 16))
            caps.append(c)
            if c == 1:
                break
        caps = caps[::-1]  # root first
        return TreeConfig(key_width=key_width, ns=ns, fs=fs,
                          leaf_fill=min(leaf_fill, ns), inner_fill=min(inner_fill, ns),
                          n_levels=len(caps), leaf_cap=leaf_cap,
                          level_caps=tuple(caps), key_cap=int(max_keys),
                          val_dtype=val_dtype, stacked=stacked)


class Level(NamedTuple):
    knum: jnp.ndarray      # int32 [C]
    plen: jnp.ndarray      # int32 [C]
    prefix: jnp.ndarray    # uint8 [C, L]
    features: jnp.ndarray  # uint8 [C, fs, ns]
    children: jnp.ndarray  # int32 [C, ns]
    anchors: jnp.ndarray   # int32 [C, ns]  (key ids)
    count: jnp.ndarray     # int32 scalar — allocation watermark


class TreeArrays(NamedTuple):
    key_bytes: jnp.ndarray   # uint8 [KC, L]
    key_lens: jnp.ndarray    # int32 [KC]
    key_tags: jnp.ndarray    # uint8 [KC] hash fingerprints (computed at append)
    key_count: jnp.ndarray   # int32 scalar
    levels: Tuple[Level, ...]
    stacked: Level           # same levels, stacked+padded to [n_levels, C_max, ...]
    leaf_tags: jnp.ndarray   # uint8 [LC, ns]
    leaf_keyid: jnp.ndarray  # int32 [LC, ns] (-1 empty)
    leaf_val: jnp.ndarray    # val_dtype [LC, ns]
    leaf_occ: jnp.ndarray    # bool [LC, ns]
    leaf_high: jnp.ndarray   # int32 [LC] key id, -1 = +inf
    leaf_next: jnp.ndarray   # int32 [LC]
    leaf_version: jnp.ndarray  # int32 [LC]
    leaf_ordered: jnp.ndarray  # bool [LC]
    leaf_count: jnp.ndarray    # int32 scalar


@jax.tree_util.register_pytree_node_class
class FBTree:
    """Pytree wrapper: arrays are leaves, config is static aux data."""

    def __init__(self, config: TreeConfig, arrays: TreeArrays):
        self.config = config
        self.arrays = arrays

    def tree_flatten(self):
        return (self.arrays,), self.config

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(aux, children[0])

    # convenience accessors
    def __getattr__(self, name):
        if name in TreeArrays._fields:
            return getattr(self.arrays, name)
        raise AttributeError(name)

    def replace(self, **kw) -> "FBTree":
        return FBTree(self.config, self.arrays._replace(**kw))

    @property
    def n_keys_live(self) -> int:
        return int(jnp.sum(self.arrays.leaf_occ))


def stack_levels(levels: Tuple[Level, ...]) -> Level:
    """Stack per-level arrays into one padded [n_levels, C_max, ...] Level.

    Rows past a level's own cap are knum=0 / children=anchors=EMPTY, so a
    backend treats them as trivial nodes (well-formed descents never land on
    them). ``count`` becomes an int32 [n_levels] vector. Pure jnp: callable
    under jit, so mutating ops can refresh the stacked copy in-graph.
    """
    C_max = max(l.knum.shape[0] for l in levels)

    def pad(a, fillv):
        short = C_max - a.shape[0]
        if short == 0:
            return a
        return jnp.concatenate(
            [a, jnp.full((short,) + a.shape[1:], fillv, a.dtype)], axis=0)

    return Level(
        knum=jnp.stack([pad(l.knum, 0) for l in levels]),
        plen=jnp.stack([pad(l.plen, 0) for l in levels]),
        prefix=jnp.stack([pad(l.prefix, 0) for l in levels]),
        features=jnp.stack([pad(l.features, 0) for l in levels]),
        children=jnp.stack([pad(l.children, EMPTY) for l in levels]),
        anchors=jnp.stack([pad(l.anchors, EMPTY) for l in levels]),
        count=jnp.stack([l.count for l in levels]),
    )


def _common_prefix_len(kb: np.ndarray, kl: np.ndarray) -> Tuple[int, np.ndarray]:
    """plen + prefix bytes over rows of a [k, L] anchor byte block."""
    L = kb.shape[1]
    if kb.shape[0] == 1:
        pl = int(min(kl[0], L))
        return pl, kb[0]
    eq = (kb == kb[:1]).all(axis=0)           # [L]
    neq = np.nonzero(~eq)[0]
    pl = int(neq[0]) if neq.size else L
    pl = int(min(pl, kl.min()))
    return pl, kb[0]


def _build_inner_level_np(cfg: TreeConfig, child_min_keyid: np.ndarray,
                          key_bytes: np.ndarray, key_lens: np.ndarray,
                          fill: int) -> Tuple[dict, np.ndarray]:
    """Group children into inner nodes; return level arrays + per-node min key id."""
    ns, fs, L = cfg.ns, cfg.fs, cfg.key_width
    n_child = child_min_keyid.shape[0]
    n_nodes = max(1, -(-n_child // fill))
    # balanced grouping
    base = n_child // n_nodes
    rem = n_child % n_nodes
    sizes = np.full(n_nodes, base, dtype=np.int64)
    sizes[:rem] += 1
    starts = np.concatenate([[0], np.cumsum(sizes)[:-1]])

    knum = np.zeros(n_nodes, dtype=np.int32)
    plen = np.zeros(n_nodes, dtype=np.int32)
    prefix = np.zeros((n_nodes, L), dtype=np.uint8)
    features = np.zeros((n_nodes, fs, ns), dtype=np.uint8)
    children = np.full((n_nodes, ns), EMPTY, dtype=np.int32)
    anchors = np.full((n_nodes, ns), EMPTY, dtype=np.int32)
    node_min = np.zeros(n_nodes, dtype=np.int32)

    for i in range(n_nodes):
        s, k = int(starts[i]), int(sizes[i])
        ids = child_min_keyid[s:s + k]
        kb = key_bytes[ids]
        kl = key_lens[ids]
        pl, pfx = _common_prefix_len(kb, kl)
        knum[i] = k
        plen[i] = pl
        prefix[i] = pfx
        for f in range(fs):
            pos = pl + f
            if pos < L:
                features[i, f, :k] = kb[:, pos]
        children[i, :k] = np.arange(s, s + k, dtype=np.int32)
        anchors[i, :k] = ids
        node_min[i] = ids[0]
    return dict(knum=knum, plen=plen, prefix=prefix, features=features,
                children=children, anchors=anchors, count=np.int32(n_nodes)), node_min


def bulk_build(cfg: TreeConfig, ks: K.KeySet, vals: np.ndarray) -> FBTree:
    """Bulk-load a tree from (possibly unsorted) unique keys. numpy host build."""
    ns, fs, L = cfg.ns, cfg.fs, cfg.key_width
    n = ks.n
    assert n <= cfg.key_cap, "key_cap exceeded"
    order = K.lex_sort_indices(ks)
    # every array gets one trailing scratch row (index cap) so masked scatters
    # have a conflict-free dump target; the watermarks never reach it.
    kb = np.zeros((cfg.key_cap + 1, L), dtype=np.uint8)
    kl = np.zeros((cfg.key_cap + 1,), dtype=np.int32)
    kb[:n] = ks.bytes[order]
    kl[:n] = ks.lens[order]
    vv = np.asarray(vals)[order]

    # ---- leaves ----
    fill = cfg.leaf_fill
    n_leaves = max(1, -(-n // fill))
    assert n_leaves <= cfg.leaf_cap, "leaf_cap exceeded"
    LC = cfg.leaf_cap + 1  # + scratch row
    leaf_tags = np.zeros((LC, ns), dtype=np.uint8)
    leaf_keyid = np.full((LC, ns), EMPTY, dtype=np.int32)
    leaf_val = np.zeros((LC, ns), dtype=np.asarray(vals).dtype)
    leaf_occ = np.zeros((LC, ns), dtype=bool)
    leaf_high = np.full((LC,), EMPTY, dtype=np.int32)
    leaf_next = np.full((LC,), EMPTY, dtype=np.int32)

    tags_all = K.fnv1a_tags(kb[:n], kl[:n])
    base = n // n_leaves
    rem = n % n_leaves
    sizes = np.full(n_leaves, base, dtype=np.int64)
    sizes[:rem] += 1
    starts = np.concatenate([[0], np.cumsum(sizes)[:-1]])
    leaf_min = np.zeros(n_leaves, dtype=np.int32)
    for i in range(n_leaves):
        s, k = int(starts[i]), int(sizes[i])
        leaf_keyid[i, :k] = np.arange(s, s + k, dtype=np.int32)
        leaf_val[i, :k] = vv[s:s + k]
        leaf_tags[i, :k] = tags_all[s:s + k]
        leaf_occ[i, :k] = True
        leaf_min[i] = s
        leaf_next[i] = i + 1 if i + 1 < n_leaves else EMPTY
        leaf_high[i] = s + k if i + 1 < n_leaves else EMPTY

    # ---- inner levels bottom-up ----
    levels_np: List[dict] = []
    child_min = leaf_min
    lvl_arrays, node_min = _build_inner_level_np(cfg, child_min, kb, kl, cfg.inner_fill)
    levels_np.append(lvl_arrays)
    while levels_np[-1]["knum"].shape[0] > 1:
        prev_n = levels_np[-1]["knum"].shape[0]
        lvl_arrays, node_min = _build_inner_level_np(cfg, node_min, kb, kl, cfg.inner_fill)
        levels_np.append(lvl_arrays)
        assert lvl_arrays["knum"].shape[0] < prev_n
    # pad to fixed height with single-child chain roots
    while len(levels_np) < cfg.n_levels:
        ids = node_min[:1]
        pl, pfx = _common_prefix_len(kb[ids], kl[ids])
        feat = np.zeros((1, fs, ns), dtype=np.uint8)
        for f in range(fs):
            if pl + f < L:
                feat[0, f, 0] = kb[ids[0], pl + f]
        levels_np.append(dict(
            knum=np.array([1], np.int32), plen=np.array([pl], np.int32),
            prefix=pfx[None].copy(), features=feat,
            children=np.full((1, ns), EMPTY, np.int32),
            anchors=np.full((1, ns), EMPTY, np.int32),
            count=np.int32(1)))
        levels_np[-1]["children"][0, 0] = 0
        levels_np[-1]["anchors"][0, 0] = ids[0]
    levels_np = levels_np[::-1]  # root first
    assert len(levels_np) == cfg.n_levels, (len(levels_np), cfg.n_levels)

    # pad each level to its cap (+1 scratch row)
    levels: List[Level] = []
    for li, lv in enumerate(levels_np):
        cap = cfg.level_caps[li]
        cur = lv["knum"].shape[0]
        assert cur <= cap, f"level {li}: {cur} > cap {cap}"

        def pad(a, fillv=0):
            out_shape = (cap + 1,) + a.shape[1:]
            out = np.full(out_shape, fillv, dtype=a.dtype)
            out[:cur] = a
            return out

        levels.append(Level(
            knum=jnp.asarray(pad(lv["knum"])),
            plen=jnp.asarray(pad(lv["plen"])),
            prefix=jnp.asarray(pad(lv["prefix"])),
            features=jnp.asarray(pad(lv["features"])),
            children=jnp.asarray(pad(lv["children"], EMPTY)),
            anchors=jnp.asarray(pad(lv["anchors"], EMPTY)),
            count=jnp.asarray(lv["count"]),
        ))

    ktags = np.zeros((cfg.key_cap + 1,), dtype=np.uint8)
    ktags[:n] = tags_all
    arrays = TreeArrays(
        key_bytes=jnp.asarray(kb), key_lens=jnp.asarray(kl),
        key_tags=jnp.asarray(ktags),
        key_count=jnp.asarray(np.int32(n)),
        levels=tuple(levels),
        stacked=stack_levels(tuple(levels)),
        leaf_tags=jnp.asarray(leaf_tags), leaf_keyid=jnp.asarray(leaf_keyid),
        leaf_val=jnp.asarray(leaf_val).astype(cfg.val_dtype),
        leaf_occ=jnp.asarray(leaf_occ),
        leaf_high=jnp.asarray(leaf_high), leaf_next=jnp.asarray(leaf_next),
        leaf_version=jnp.zeros((LC,), jnp.int32),
        leaf_ordered=jnp.asarray(np.arange(LC) < n_leaves),
        leaf_count=jnp.asarray(np.int32(n_leaves)),
    )
    return FBTree(cfg, arrays)


def tree_to_device(tree: FBTree) -> FBTree:
    return jax.tree_util.tree_map(jnp.asarray, tree)
