"""Leaf-node operations: hashtag probe (paper Fig. 6 lines 30-42) + slot ops."""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from .fbtree import FBTree
from .keys import fnv1a_tags

__all__ = ["LeafStats", "probe", "verify_candidates", "find_free_slots"]


class LeafStats(NamedTuple):
    tag_candidates: jnp.ndarray  # int32 [B] slots passing the hashtag filter
    lines_touched: jnp.ndarray   # int32 [B]

    @staticmethod
    def zeros(b: int):
        z = jnp.zeros((b,), jnp.int32)
        return LeafStats(z, z)


def verify_candidates(a, cand: jnp.ndarray, kid: jnp.ndarray,
                      qb: jnp.ndarray, ql: jnp.ndarray,
                      ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Exact-match verification over the hashtag candidate mask.

    Checks candidates one at a time in slot order (a ``lax.while_loop``
    whose trip count is the deepest candidate rank any still-unmatched lane
    needs — typically 1): per round one ``[B, L]`` key gather and compare,
    instead of materializing all ``[B, ns, L]`` leaf key bytes. This is the
    paper's line 36-38 claim executed literally — key cache lines are
    touched *only* for candidates — and it is observationally identical to
    the all-at-once verify: ``found``/``slot`` match bit for bit (first
    matching candidate wins in both formulations; slot 0 when none).
    """
    B, ns = cand.shape
    crank = jnp.cumsum(cand.astype(jnp.int32), axis=-1) - 1  # cand rank/slot
    n_cand = cand.sum(-1).astype(jnp.int32)
    lane = jnp.arange(ns, dtype=jnp.int32)[None, :]

    def cond(c):
        checked, found, _ = c
        return ((~found) & (checked < n_cand)).any()

    def body(c):
        checked, found, slot = c
        active = (~found) & (checked < n_cand)
        is_k = cand & (crank == checked[:, None])
        s = jnp.min(jnp.where(is_k, lane, ns), axis=-1)
        s = jnp.where(active, jnp.minimum(s, ns - 1), 0)
        kd = jnp.maximum(kid[jnp.arange(B), s], 0)
        akb = a.key_bytes[kd]                               # [B, L]
        akl = a.key_lens[kd]
        eqk = (akb == qb).all(-1) & (akl == ql) & active
        slot = jnp.where(eqk, s, slot)
        return checked + active.astype(jnp.int32), found | eqk, slot

    init = (jnp.zeros((B,), jnp.int32), jnp.zeros((B,), bool),
            jnp.zeros((B,), jnp.int32))
    _, found, slot = jax.lax.while_loop(cond, body, init)
    return found, slot


def probe(tree: FBTree, leaf_ids: jnp.ndarray, qb: jnp.ndarray, ql: jnp.ndarray,
          collect_stats: bool = True,
          ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray, LeafStats]:
    """Find each query's slot in its leaf.

    Returns (found [B]bool, slot [B]int32, val [B], stats). The hashtag filter
    narrows candidates exactly as the paper's ``compare_equal(tags, tag)``;
    verification compares full key bytes (lines 36-38) candidate-by-candidate
    (:func:`verify_candidates` — key lines touched only for candidates, both
    here and in the Pallas wrapper ``kernels/leaf_probe``).
    ``collect_stats=False`` skips the counter reductions and returns
    ``stats=None`` (the candidate mask itself is load-bearing and stays).
    """
    a = tree.arrays
    ns = a.leaf_tags.shape[-1]
    qtag = fnv1a_tags(qb, ql)
    tags = a.leaf_tags[leaf_ids]              # [B, ns]
    occ = a.leaf_occ[leaf_ids]
    cand = (tags == qtag[:, None]) & occ
    kid = a.leaf_keyid[leaf_ids]              # [B, ns]
    found, slot = verify_candidates(a, cand, kid, qb, ql)
    val = jnp.take_along_axis(a.leaf_val[leaf_ids], slot[:, None], axis=-1)[:, 0]
    val = jnp.where(found, val, 0)
    if not collect_stats:
        return found, slot, val, None
    n_cand = cand.sum(-1).astype(jnp.int32)
    kw_lines = (ql + 63) // 64
    stats = LeafStats(
        tag_candidates=n_cand,
        # modeled: control+tags row (ns bytes -> ns/64 lines) + bitmap word +
        # per-candidate kv pointer line + key line(s)
        lines_touched=(max(1, ns // 64) + 1 + n_cand * (1 + kw_lines)).astype(jnp.int32),
    )
    return found, slot, val, stats


def find_free_slots(occ_row: jnp.ndarray, count: jnp.ndarray) -> jnp.ndarray:
    """Rank free slots of a leaf row: returns int32 [ns] where entry r is the
    slot index of the r-th free slot (ns if fewer free slots exist)."""
    ns = occ_row.shape[-1]
    free = ~occ_row
    order = jnp.argsort(jnp.where(free, jnp.arange(ns), ns + jnp.arange(ns)))
    nfree = free.sum()
    rank_valid = jnp.arange(ns) < jnp.minimum(nfree, count)
    return jnp.where(rank_valid, order, ns)
