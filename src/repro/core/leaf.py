"""Leaf-node operations: hashtag probe (paper Fig. 6 lines 30-42) + slot ops."""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax.numpy as jnp

from .fbtree import FBTree
from .keys import fnv1a_tags

__all__ = ["LeafStats", "probe", "find_free_slots"]


class LeafStats(NamedTuple):
    tag_candidates: jnp.ndarray  # int32 [B] slots passing the hashtag filter
    lines_touched: jnp.ndarray   # int32 [B]

    @staticmethod
    def zeros(b: int):
        z = jnp.zeros((b,), jnp.int32)
        return LeafStats(z, z)


def probe(tree: FBTree, leaf_ids: jnp.ndarray, qb: jnp.ndarray, ql: jnp.ndarray,
          ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray, LeafStats]:
    """Find each query's slot in its leaf.

    Returns (found [B]bool, slot [B]int32, val [B], stats). The hashtag filter
    narrows candidates exactly as the paper's ``compare_equal(tags, tag)``;
    verification compares full key bytes (lines 36-38). The jnp oracle
    verifies all candidates at once; the Pallas kernel (kernels/leaf_probe)
    streams tag rows first and touches key lines only for candidates.
    """
    a = tree.arrays
    ns = a.leaf_tags.shape[-1]
    qtag = fnv1a_tags(qb, ql)
    tags = a.leaf_tags[leaf_ids]              # [B, ns]
    occ = a.leaf_occ[leaf_ids]
    cand = (tags == qtag[:, None]) & occ
    kid = a.leaf_keyid[leaf_ids]              # [B, ns]
    kid_safe = jnp.maximum(kid, 0)
    akb = a.key_bytes[kid_safe]               # [B, ns, L]
    akl = a.key_lens[kid_safe]
    eqfull = (akb == qb[:, None, :]).all(-1) & (akl == ql[:, None]) & cand
    found = eqfull.any(-1)
    slot = jnp.argmax(eqfull, axis=-1).astype(jnp.int32)
    val = jnp.take_along_axis(a.leaf_val[leaf_ids], slot[:, None], axis=-1)[:, 0]
    val = jnp.where(found, val, 0)
    n_cand = cand.sum(-1).astype(jnp.int32)
    kw_lines = (ql + 63) // 64
    stats = LeafStats(
        tag_candidates=n_cand,
        # modeled: control+tags row (ns bytes -> ns/64 lines) + bitmap word +
        # per-candidate kv pointer line + key line(s)
        lines_touched=(max(1, ns // 64) + 1 + n_cand * (1 + kw_lines)).astype(jnp.int32),
    )
    return found, slot, val, stats


def find_free_slots(occ_row: jnp.ndarray, count: jnp.ndarray) -> jnp.ndarray:
    """Rank free slots of a leaf row: returns int32 [ns] where entry r is the
    slot index of the r-th free slot (ns if fewer free slots exist)."""
    ns = occ_row.shape[-1]
    free = ~occ_row
    order = jnp.argsort(jnp.where(free, jnp.arange(ns), ns + jnp.arange(ns)))
    nfree = free.sum()
    rank_valid = jnp.arange(ns) < jnp.minimum(nfree, count)
    return jnp.where(rank_valid, order, ns)
