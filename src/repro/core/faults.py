"""Seeded, replayable fault injection for the tree lifecycle (DESIGN.md §8).

The harness mirrors ``core.protocol.Sim``'s determinism contract: a
``FaultPlan(seed=...)`` replays the exact same fault schedule for the same
sequence of instrumented calls, so every chaos failure is reproducible
from its seed. Faults come in four kinds:

* ``abort``       raise :class:`FaultInjected` at a lifecycle step — the
  staged build dies, the published version must keep serving.
* ``corrupt``     structurally damage a **staged** (never published) tree;
  ``core.fsck.check_tree`` must catch it before the swap.
* ``drop_shard``  raise :class:`ShardDropped` at a dispatch site — the
  shard is unreachable for this launch (its arrays are intact; only the
  dispatch fails). Random-mode drops are *sticky* until :meth:`heal`,
  modeling a down shard; explicit ``FaultSpec`` drops fire per their
  ``nth``/``count`` window, modeling transient flakes that retries absorb.
* ``delay``       sleep a bounded jitter before a routed op (exercises the
  async combine without changing results).

Fault *sites* are dotted names (``lifecycle.rebuild.gather``,
``shard.dispatch.lookup``, ...); specs match them with ``fnmatch``
patterns. Instrumented code calls :meth:`FaultPlan.fire` at each site —
with no plan (or a disarmed one) that is a no-op, so fault-free paths stay
bit-identical to the uninstrumented code.
"""
from __future__ import annotations

import dataclasses
import random
import time
from fnmatch import fnmatch
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from repro import obs

__all__ = ["FaultInjected", "ShardDropped", "FaultSpec", "FaultPlan",
           "RetryPolicy", "CORRUPTIONS", "corrupt_tree"]


class FaultInjected(RuntimeError):
    """An injected fault fired at ``site`` (kind ``abort`` unless raised as
    a subclass). Carries enough context to assert on in tests."""

    def __init__(self, site: str, kind: str = "abort",
                 shard: Optional[int] = None):
        self.site = site
        self.kind = kind
        self.shard = shard
        at = f" shard={shard}" if shard is not None else ""
        super().__init__(f"injected {kind} at {site}{at}")


class ShardDropped(FaultInjected):
    """A shard was unreachable for one dispatch attempt. The shard's
    arrays are intact — only this launch failed — so retry/degrade is the
    correct response, never data re-construction."""

    def __init__(self, site: str, shard: Optional[int] = None):
        super().__init__(site, kind="drop_shard", shard=shard)


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """One deterministic fault: fire ``kind`` at sites matching ``site``
    (an ``fnmatch`` pattern), on visits ``[nth, nth + count)`` of that
    spec's per-(spec, shard) counter (``count=-1`` = every visit from
    ``nth`` on). ``shard`` narrows dispatch faults to one shard."""
    site: str
    kind: str = "abort"
    nth: int = 0
    count: int = -1
    shard: Optional[int] = None
    delay: float = 0.0


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """Capped-exponential-backoff retry for routed dispatch. ``sleep`` is
    injectable so tests and the chaos sweep run at full speed."""
    max_attempts: int = 3
    base_delay: float = 0.001
    max_delay: float = 0.05
    sleep: Callable[[float], None] = time.sleep

    def delays(self):
        d = self.base_delay
        for _ in range(max(0, self.max_attempts - 1)):
            yield d
            d = min(d * 2.0, self.max_delay)


class FaultPlan:
    """A replayable fault schedule.

    Two modes, composable:

    * **explicit** — a tuple of :class:`FaultSpec`; deterministic given the
      call sequence (used by regression tests).
    * **random**   — ``p={"abort": 0.3, "drop_shard": 0.2, ...}`` draws
      from a private ``random.Random(seed)`` at each eligible site; the
      same seed replays the same schedule (used by the chaos sweep).

    ``disarm()`` turns the plan off (recovery phases run fault-free);
    ``heal()`` clears sticky shard drops. ``events`` logs every fired
    fault as ``(site, kind, shard)`` for replay comparison.
    """

    KINDS = ("abort", "corrupt", "drop_shard", "delay")
    # random-mode faults only fire where they are meaningful
    _RANDOM_PREFIX = {"abort": "lifecycle.", "drop_shard": "shard.dispatch",
                      "delay": "shard.dispatch"}

    def __init__(self, specs: Tuple[FaultSpec, ...] = (), seed: int = 0xFB,
                 p: Optional[Dict[str, float]] = None,
                 sleep: Optional[Callable[[float], None]] = None):
        for s in specs:
            if s.kind not in self.KINDS:
                raise ValueError(f"unknown fault kind {s.kind!r}; "
                                 f"one of {self.KINDS}")
        self.specs = tuple(specs)
        self.seed = int(seed)
        self.rng = random.Random(self.seed)
        self.p = dict(p or {})
        self.sleep = sleep if sleep is not None else (
            lambda s: time.sleep(min(s, 0.005)))
        self.armed = True
        self.events: List[Tuple[str, str, Optional[int]]] = []
        self._visits: Dict[Tuple[int, Optional[int]], int] = {}
        self._dropped: set = set()

    # ------------------------------------------------------------ control
    def disarm(self):
        self.armed = False

    def arm(self):
        self.armed = True

    def heal(self, shard: Optional[int] = None):
        """Clear sticky shard drops (all shards, or one)."""
        if shard is None:
            self._dropped.clear()
        else:
            self._dropped.discard(shard)

    # ------------------------------------------------------------- firing
    def _spec_fires(self, si: int, spec: FaultSpec, site: str,
                    shard: Optional[int]) -> bool:
        if not fnmatch(site, spec.site):
            return False
        if spec.shard is not None and spec.shard != shard:
            return False
        key = (si, shard)
        n = self._visits.get(key, 0)
        self._visits[key] = n + 1
        if n < spec.nth:
            return False
        return spec.count < 0 or n < spec.nth + spec.count

    def fire(self, site: str, shard: Optional[int] = None, **ctx) -> None:
        """Instrumentation hook: raise/delay if a fault is scheduled here.

        ``corrupt`` faults never fire here — they go through
        :meth:`corrupt_staged` (they need the staged object in hand).
        """
        if not self.armed:
            return
        if (shard is not None and shard in self._dropped
                and site.startswith("shard.dispatch")):
            self.events.append((site, "drop_shard", shard))
            obs.event("fault", site=site, kind="drop_shard", seed=self.seed,
                      shard=shard)
            raise ShardDropped(site, shard=shard)
        for si, spec in enumerate(self.specs):
            if spec.kind == "corrupt":
                continue
            if self._spec_fires(si, spec, site, shard):
                self._do(spec.kind, site, shard, delay=spec.delay,
                         sticky=False)
        for kind in sorted(self.p):
            if kind == "corrupt":
                continue
            prefix = self._RANDOM_PREFIX.get(kind, "")
            if not site.startswith(prefix):
                continue
            if self.rng.random() < self.p[kind]:
                self._do(kind, site, shard, sticky=True)

    def _do(self, kind: str, site: str, shard: Optional[int],
            delay: float = 0.0, sticky: bool = False):
        self.events.append((site, kind, shard))
        obs.event("fault", site=site, kind=kind, seed=self.seed,
                  shard=shard)
        if kind == "abort":
            raise FaultInjected(site, "abort", shard)
        if kind == "drop_shard":
            if sticky and shard is not None:
                self._dropped.add(shard)
            raise ShardDropped(site, shard=shard)
        if kind == "delay":
            self.sleep(delay if delay > 0 else self.rng.uniform(0, 0.003))

    def corrupt_staged(self, site: str, obj):
        """Maybe structurally corrupt a staged tree. Returns
        ``(obj', fired)`` — ``obj`` untouched when nothing fires. Only ever
        called on staged (unpublished) objects by the lifecycle layer."""
        if not self.armed:
            return obj, False
        fired = False
        for si, spec in enumerate(self.specs):
            if spec.kind != "corrupt":
                continue
            if self._spec_fires(si, spec, site, None):
                fired = True
        if not fired and self.rng.random() < self.p.get("corrupt", 0.0):
            fired = True
        if not fired:
            return obj, False
        obj2, kind = corrupt_tree(obj, self.rng)
        self.events.append((site, f"corrupt:{kind}", None))
        obs.event("fault", site=site, kind=f"corrupt:{kind}",
                  seed=self.seed)
        return obj2, True


# --------------------------------------------------------------------------
# structural corruptions — every one is guaranteed fsck-detectable
# --------------------------------------------------------------------------

CORRUPTIONS = ("anchor_swap", "chain_break", "high_key", "phantom_slot",
               "knum_bump", "dup_keyid", "key_count")


def _with_levels(tree, levels):
    import jax.numpy as jnp
    from .fbtree import Level
    jlv = tuple(Level(*[jnp.asarray(x) for x in lv]) for lv in levels)
    # deliberately NOT refreshing `stacked`: a real torn write desyncs the
    # layouts, and fsck's coherence check must catch that too
    return tree.replace(levels=jlv)


def _apply_corruption(tree, rng: random.Random, kind: str):
    """Try one corruption on an FBTree; None when inapplicable."""
    a = tree.arrays
    leaf_count = int(a.leaf_count)
    kc = int(a.key_count)
    occ = np.asarray(a.leaf_occ)[:leaf_count]

    if kind == "chain_break":
        ln = np.array(a.leaf_next)
        ln[0] = 0                      # self-cycle (lone leaf included)
        import jax.numpy as jnp
        return tree.replace(leaf_next=jnp.asarray(ln))

    if kind == "key_count":
        if not occ.any():
            return None
        import jax.numpy as jnp
        return tree.replace(key_count=jnp.int32(0))

    if kind == "high_key":
        import jax.numpy as jnp
        lh = np.array(a.leaf_high)
        kid = np.asarray(a.leaf_keyid)
        cand = [i for i in range(leaf_count)
                if lh[i] != -1 and occ[i].any()]
        if not cand:
            return None
        i = cand[rng.randrange(len(cand))]
        slot = int(np.nonzero(occ[i])[0][0])
        lh[i] = kid[i, slot]           # a key in the leaf: key < high fails
        return tree.replace(leaf_high=jnp.asarray(lh))

    if kind == "phantom_slot":
        import jax.numpy as jnp
        free = ~occ
        if not free.any():
            return None
        r, s = map(int, np.argwhere(free)[rng.randrange(free.sum())])
        lo = np.array(a.leaf_occ)
        lk = np.array(a.leaf_keyid)
        lo[r, s] = True
        lk[r, s] = kc                  # points past the pool watermark
        return tree.replace(leaf_occ=jnp.asarray(lo),
                            leaf_keyid=jnp.asarray(lk))

    if kind == "dup_keyid":
        import jax.numpy as jnp
        live = np.argwhere(occ)
        if live.shape[0] < 2:
            return None
        (r1, s1), (r2, s2) = live[0], live[1]
        lk = np.array(a.leaf_keyid)
        lk[r2, s2] = lk[r1, s1]
        return tree.replace(leaf_keyid=jnp.asarray(lk))

    # inner-level corruptions work on the bottom inner level
    bot = len(a.levels) - 1
    lv = a.levels[bot]
    cnt = int(lv.count)
    knum = np.asarray(lv.knum)

    if kind == "anchor_swap":
        rows = [r for r in range(cnt) if knum[r] >= 2]
        if not rows:
            return None
        r = rows[rng.randrange(len(rows))]
        anchors = np.array(lv.anchors)
        anchors[r, 0], anchors[r, 1] = anchors[r, 1], anchors[r, 0]
        levels = [list(l) for l in a.levels]
        levels[bot][5] = anchors
        return _with_levels(tree, levels)

    if kind == "knum_bump":
        ns = tree.config.ns
        rows = [r for r in range(cnt) if knum[r] < ns]
        if not rows:
            return None
        r = rows[rng.randrange(len(rows))]
        kn = knum.copy()
        kn[r] += 1                     # exposes an EMPTY pad lane
        levels = [list(l) for l in a.levels]
        levels[bot][0] = kn
        return _with_levels(tree, levels)

    raise ValueError(f"unknown corruption kind {kind!r}")


def corrupt_tree(tree, rng: random.Random, kind: Optional[str] = None):
    """Structurally corrupt a tree (FBTree or ShardedTree) such that
    ``core.fsck`` is guaranteed to flag it. Returns ``(tree', kind)``.

    With ``kind=None`` a random applicable corruption is chosen;
    ``chain_break`` is the universal fallback (applies to any tree).
    """
    if hasattr(tree, "shards"):        # ShardedTree (duck-typed: no import
        s = rng.randrange(len(tree.shards))   # cycle with repro.shard)
        t2, k = corrupt_tree(tree.shards[s], rng, kind=kind)
        shards = list(tree.shards)
        shards[s] = t2
        return tree.replace(shards=tuple(shards)), k
    kinds = [kind] if kind is not None else list(CORRUPTIONS)
    if kind is None:
        rng.shuffle(kinds)
    for k in kinds + ["chain_break"]:
        t2 = _apply_corruption(tree, rng, k)
        if t2 is not None:
            return t2, k
    raise AssertionError("unreachable: chain_break always applies")
