"""Key handling for FB+-tree: order-preserving byte encodings and key sets.

Keys are arbitrary byte strings. Device-side they live in a fixed-width,
zero-padded ``uint8[N, max_key_len]`` array plus ``int32[N]`` lengths. Order is
lexicographic over bytes with a length tie-break, which equals true
bytes-order as long as comparisons fall back to length when the padded bytes
are identical (a zero-padded key compares equal to its own prefix key).

The paper's §3.6 trick (add 128 to signed bytes so unsigned SIMD compares
work) appears here as the sign-bit flip in :func:`encode_int64`: signed
integers become order-preserving unsigned byte strings, after which all
comparisons in the tree are plain unsigned byte compares.
"""
from __future__ import annotations

from typing import Iterable, NamedTuple, Sequence, Union

import numpy as np

__all__ = [
    "KeySet",
    "encode_uint64",
    "encode_int64",
    "decode_uint64",
    "make_keyset",
    "pack_words",
    "lex_sort_indices",
    "lex_sort_indices_j",
    "compare_padded",
    "fnv1a_tags",
]


def encode_uint64(x: Union[int, np.ndarray]) -> np.ndarray:
    """uint64 -> big-endian 8 bytes (order-preserving)."""
    x = np.asarray(x, dtype=np.uint64)
    out = np.empty(x.shape + (8,), dtype=np.uint8)
    for i in range(8):
        out[..., i] = ((x >> np.uint64(8 * (7 - i))) & np.uint64(0xFF)).astype(np.uint8)
    return out


def encode_int64(x: Union[int, np.ndarray]) -> np.ndarray:
    """int64 -> order-preserving 8 bytes via sign-bit flip (paper §3.6)."""
    x = np.atleast_1d(np.asarray(x, dtype=np.int64))
    flipped = x.view(np.uint64) ^ np.uint64(1 << 63)
    return encode_uint64(flipped)


def decode_uint64(b: np.ndarray) -> np.ndarray:
    b = np.asarray(b, dtype=np.uint64)
    acc = np.zeros(b.shape[:-1], dtype=np.uint64)
    for i in range(8):
        acc = (acc << np.uint64(8)) | b[..., i]
    return acc


class KeySet(NamedTuple):
    """Fixed-width padded key batch."""

    bytes: np.ndarray  # uint8 [N, L] zero padded
    lens: np.ndarray   # int32 [N]

    @property
    def n(self) -> int:
        return int(self.bytes.shape[0])

    @property
    def width(self) -> int:
        return int(self.bytes.shape[1])


def make_keyset(keys: Sequence[Union[bytes, str, int]], max_key_len: int,
                int_mode: str = "uint64") -> KeySet:
    """Build a KeySet from python keys (bytes / str / int)."""
    rows = []
    lens = []
    for k in keys:
        if isinstance(k, str):
            k = k.encode("utf-8")
        if isinstance(k, (int, np.integer)):
            k = (encode_int64(int(k)) if int_mode == "int64"
                 else encode_uint64(int(k))).tobytes()
        if len(k) > max_key_len:
            raise ValueError(f"key longer than max_key_len={max_key_len}: {len(k)}")
        rows.append(k)
        lens.append(len(k))
    arr = np.zeros((len(rows), max_key_len), dtype=np.uint8)
    for i, r in enumerate(rows):
        arr[i, : len(r)] = np.frombuffer(r, dtype=np.uint8)
    return KeySet(arr, np.asarray(lens, dtype=np.int32))


def pack_words(kb: np.ndarray) -> np.ndarray:
    """Pack uint8 [.., L] into big-endian int32 words [.., ceil(L/4)].

    Packed words compare (as *unsigned*; we bias to keep int32 order correct)
    in the same order as the bytes, enabling O(L/4) lexsort keys.
    """
    n, L = kb.shape[0], kb.shape[-1]
    Lp = (L + 3) // 4 * 4
    if Lp != L:
        pad = np.zeros(kb.shape[:-1] + (Lp - L,), dtype=np.uint8)
        kb = np.concatenate([kb, pad], axis=-1)
    w = kb.reshape(kb.shape[:-1] + (Lp // 4, 4)).astype(np.uint32)
    words = (w[..., 0] << 24) | (w[..., 1] << 16) | (w[..., 2] << 8) | w[..., 3]
    # bias so that int32 ordering == unsigned ordering
    return (words.astype(np.int64) - (1 << 31)).astype(np.int32)


def pack_words_j(kb) -> "jnp.ndarray":
    """jnp version of :func:`pack_words` (order-preserving int32 words)."""
    import jax.numpy as jnp
    L = kb.shape[-1]
    Lp = (L + 3) // 4 * 4
    if Lp != L:
        pad = jnp.zeros(kb.shape[:-1] + (Lp - L,), dtype=jnp.uint8)
        kb = jnp.concatenate([kb, pad], axis=-1)
    w = kb.reshape(kb.shape[:-1] + (Lp // 4, 4)).astype(jnp.uint32)
    words = (w[..., 0] << 24) | (w[..., 1] << 16) | (w[..., 2] << 8) | w[..., 3]
    return (words ^ jnp.uint32(1 << 31)).astype(jnp.int32)


def lex_sort_indices(ks: KeySet) -> np.ndarray:
    """Indices that sort the KeySet lexicographically (bytes, then length)."""
    words = pack_words(ks.bytes)  # [N, W]
    cols = [ks.lens] + [words[:, i] for i in range(words.shape[1] - 1, -1, -1)]
    return np.lexsort(cols)


def lex_sort_indices_j(kb, kl, invalid=None) -> "jnp.ndarray":
    """jnp twin of :func:`lex_sort_indices`: device argsort of padded keys by
    (bytes asc, length tie-break), optionally pushing rows flagged by the
    bool mask ``invalid`` past every valid row. Single definition of the
    device key order — the build and rebuild paths (DESIGN.md §5) must sort
    identically for host/device parity to hold.
    """
    import jax.numpy as jnp
    words = pack_words_j(kb)  # [N, W] order-preserving int32
    cols = [kl] + [words[:, i] for i in range(words.shape[1] - 1, -1, -1)]
    if invalid is not None:
        cols.append(invalid.astype(jnp.int32))  # most significant: valid first
    return jnp.lexsort(cols)


def compare_padded(a_bytes: np.ndarray, a_len: np.ndarray,
                   b_bytes: np.ndarray, b_len: np.ndarray) -> np.ndarray:
    """Vectorized 3-way compare (-1/0/1) on padded keys with length tie-break.

    Shapes broadcast on the leading dims; last dim is key width.
    Works for numpy and jax.numpy arrays alike.
    """
    xp = np  # both numpy & jnp expose the same API surface used here
    try:  # allow jnp arrays transparently
        import jax.numpy as jnp
        if any(hasattr(x, "aval") or type(x).__module__.startswith("jax")
               for x in (a_bytes, b_bytes)):
            xp = jnp
    except Exception:  # pragma: no cover
        pass
    a = a_bytes.astype(xp.int32)
    b = b_bytes.astype(xp.int32)
    diff = a - b
    nz = diff != 0
    # first nonzero byte position; width if all equal
    width = a.shape[-1]
    idx = xp.argmax(nz, axis=-1)
    anynz = nz.any(axis=-1)
    first = xp.where(anynz, xp.take_along_axis(diff, idx[..., None], axis=-1)[..., 0], 0)
    byte_cmp = xp.sign(first)
    len_cmp = xp.sign(a_len - b_len)
    return xp.where(anynz, byte_cmp, len_cmp).astype(xp.int32)


def fnv1a_tags(kb: np.ndarray, klen: np.ndarray) -> np.ndarray:
    """1-byte FNV-1a-style fingerprints over the valid bytes of each key.

    Vectorized and jnp-compatible: masked positions contribute the identity.
    Matches the role of ``tags`` in the paper's leaf nodes.
    """
    xp = np
    try:
        import jax.numpy as jnp
        if type(kb).__module__.startswith("jax"):
            xp = jnp
    except Exception:  # pragma: no cover
        pass
    L = kb.shape[-1]
    h = xp.full(kb.shape[:-1], 0x811C9DC5, dtype=xp.uint32)
    pos = xp.arange(L, dtype=xp.int32)
    for i in range(L):
        valid = (pos[i] < klen)
        byte = kb[..., i].astype(xp.uint32)
        nh = (h ^ byte) * xp.uint32(0x01000193)
        h = xp.where(valid, nh, h)
    # fold to one byte
    h = (h ^ (h >> 16)) & xp.uint32(0xFFFF)
    h = (h ^ (h >> 8)) & xp.uint32(0xFF)
    return h.astype(xp.uint8)
