"""Structural fsck over real ``TreeArrays`` (DESIGN.md §8).

``core.protocol.check_invariants`` validates the §2 concurrency protocol
on a *simulated* tree; this module ports the same invariants — chain
order, high-key coverage, accounting — to the actual device arrays, plus
everything the structure-of-arrays layout adds (anchor order, DFS
reachability, meta coherence, stacked/tuple layout agreement). It is the
gate :class:`core.lifecycle.TreeVersionManager` runs on every staged tree
before a publish swap: a corrupted or half-built version can never become
the serving version.

Checks are host-side numpy (one device→host pull per array) and
O(n_live + nodes) — cheap next to the rebuild they guard. Key comparisons
use a dense rank over the pool (equal ``(bytes, len)`` rows share a rank),
so strict/non-strict boundary semantics are exact even when tombstoned
pool rows duplicate live key bytes.

Invariants (each has a corruption in ``core.faults.CORRUPTIONS`` proving
it detectable):

1. watermarks in range; no occupied slot outside ``[0, leaf_count)`` rows.
2. every occupied slot's key id in ``[0, key_count)``; ids unique; live
   key *bytes* unique.
3. leaf chain from leaf 0: cycle-free, visits exactly the allocated
   leaves; ``leaf_high`` EMPTY iff last; high keys strictly ascending.
4. high-key coverage: every live key < its leaf's high; the next leaf's
   keys >= it (protocol.py's ``high_key``/order invariant).
5. ``leaf_ordered`` leaves really are ascending in slot order.
6. inner nodes: valid lanes non-EMPTY, pad lanes EMPTY, anchors strictly
   ascending, child ids in range.
7. DFS from the root reaches every allocated node/leaf exactly once,
   leaf order equals chain order, and every live key lies in its leaf's
   ``[lo, hi)`` anchor bounds.
8. ``plen``/``prefix``/``features`` equal ``recompute_inner_meta`` of the
   anchors (the §3 SIMD metadata is derived state — it must agree).
9. ``stacked`` equals ``stack_levels(levels)`` (layout coherence).
10. ``leaf_version`` >= 0, and (vs an optional ``prev`` snapshot) versions
    never regress on surviving leaves — §4.2 monotonicity.
"""
from __future__ import annotations

from typing import List, NamedTuple, Optional, Tuple

import numpy as np

from .fbtree import FBTree, stack_levels

__all__ = ["FsckReport", "check_tree", "check_sharded", "check",
           "assert_ok"]

_EMPTY = -1


class FsckReport(NamedTuple):
    ok: bool
    violations: Tuple[str, ...]
    n_live: int
    n_leaves: int

    def __bool__(self) -> bool:  # `if fsck.check(t):` reads naturally
        return self.ok


def _key_ranks(kb: np.ndarray, kl: np.ndarray) -> np.ndarray:
    """Dense order rank per pool row; equal (bytes, len) rows share a rank.

    Row-lexicographic order over ``bytes ‖ len_be`` is exactly the tree's
    key order (padded-byte compare with the length tie-break).
    """
    if kb.shape[0] == 0:
        return np.zeros((0,), np.int64)
    lens_be = kl.astype(">u4").view(np.uint8).reshape(kl.shape[0], 4)
    rows = np.concatenate([kb, lens_be], axis=1)
    _, inv = np.unique(rows, axis=0, return_inverse=True)
    return inv.astype(np.int64)


def check_tree(tree: FBTree, name: str = "tree",
               prev: Optional[FBTree] = None,
               max_violations: int = 20) -> FsckReport:
    """Run every structural invariant; collect up to ``max_violations``."""
    cfg = tree.config
    a = tree.arrays
    v: List[str] = []

    def bad(msg: str):
        if len(v) < max_violations:
            v.append(f"{name}: {msg}")

    kb = np.asarray(a.key_bytes)
    kl = np.asarray(a.key_lens)
    kc = int(a.key_count)
    occ = np.asarray(a.leaf_occ)
    kid = np.asarray(a.leaf_keyid)
    high = np.asarray(a.leaf_high)
    nxt = np.asarray(a.leaf_next)
    ver = np.asarray(a.leaf_version)
    ordered = np.asarray(a.leaf_ordered)
    leaf_count = int(a.leaf_count)
    LCAP = cfg.leaf_cap

    # ---- 1: watermarks + allocation hygiene ----
    if not (0 <= kc <= cfg.key_cap):
        bad(f"key_count {kc} outside [0, key_cap={cfg.key_cap}]")
    if not (1 <= leaf_count <= LCAP):
        bad(f"leaf_count {leaf_count} outside [1, leaf_cap={LCAP}]")
        leaf_count = max(1, min(leaf_count, LCAP))
    if occ[leaf_count:].any():
        bad(f"occupied slots in {int(occ[leaf_count:].any(axis=1).sum())} "
            f"rows at/above the leaf watermark {leaf_count}")

    # ---- 2: live key ids ----
    locc = occ[:leaf_count]
    lkid = kid[:leaf_count]
    oob = locc & ((lkid < 0) | (lkid >= kc))
    if oob.any():
        bad(f"{int(oob.sum())} occupied slots with key id outside "
            f"[0, key_count={kc})")
    live_ids = lkid[locc & ~oob]
    if live_ids.size != np.unique(live_ids).size:
        bad("duplicate key id across occupied leaf slots")
    ranks = _key_ranks(kb, kl)
    live_rank = ranks[live_ids] if live_ids.size else np.zeros(0, np.int64)
    if live_rank.size != np.unique(live_rank).size:
        bad("duplicate live key bytes (two occupied slots, same key)")
    n_live = int(locc.sum())

    # per-leaf rank rows: rank of each occupied slot, -1 elsewhere
    slot_rank = np.full(locc.shape, -1, np.int64)
    ok_slots = locc & ~oob
    slot_rank[ok_slots] = ranks[lkid[ok_slots]]

    def leaf_min(i):
        r = slot_rank[i][slot_rank[i] >= 0]
        return int(r.min()) if r.size else None

    def leaf_max(i):
        r = slot_rank[i][slot_rank[i] >= 0]
        return int(r.max()) if r.size else None

    # ---- 3: leaf chain ----
    chain: List[int] = []
    seen = np.zeros(occ.shape[0], bool)
    cur = 0
    while cur != _EMPTY:
        if not (0 <= cur < leaf_count):
            bad(f"leaf chain points at unallocated leaf {cur}")
            break
        if seen[cur]:
            bad(f"leaf chain cycles back to leaf {cur}")
            break
        seen[cur] = True
        chain.append(cur)
        cur = int(nxt[cur])
    if len(chain) != leaf_count:
        bad(f"leaf chain visits {len(chain)} of {leaf_count} "
            f"allocated leaves")

    # ---- 3/4: high keys + order along the chain ----
    prev_high_rank = None
    for pos, i in enumerate(chain):
        last = pos == len(chain) - 1
        h = int(high[i])
        if (h == _EMPTY) != (int(nxt[i]) == _EMPTY):
            bad(f"leaf {i}: high-key EMPTY must coincide with chain end "
                f"(high={h}, next={int(nxt[i])})")
        if h != _EMPTY and not (0 <= h < max(kc, 1)):
            bad(f"leaf {i}: high key id {h} outside the pool watermark")
            h = _EMPTY
        hr = None if h == _EMPTY else int(ranks[h])
        mx, mn = leaf_max(i), leaf_min(i)
        if hr is not None and mx is not None and not (mx < hr):
            bad(f"leaf {i}: live key >= its high key")
        if prev_high_rank is not None:
            if hr is not None and not (prev_high_rank < hr):
                bad(f"leaf {i}: high keys not ascending along the chain")
            if mn is not None and mn < prev_high_rank:
                bad(f"leaf {i}: live key below the previous leaf's high "
                    f"key (chain order broken)")
        if not last and hr is not None:
            prev_high_rank = hr
        # ---- 5: ordered leaves are really ordered ----
        if bool(ordered[i]):
            sr = slot_rank[i][slot_rank[i] >= 0]
            idx = np.nonzero(slot_rank[i] >= 0)[0]
            if sr.size > 1 and not (np.diff(slot_rank[i][idx]) > 0).all():
                bad(f"leaf {i}: marked ordered but slots are not "
                    f"ascending")

    # ---- 10: versions ----
    if (ver[:leaf_count] < 0).any():
        bad("negative leaf version")
    if prev is not None and prev.config == cfg:
        pv = np.asarray(prev.arrays.leaf_version)
        plc = int(prev.arrays.leaf_count)
        if leaf_count < plc:
            bad(f"leaf_count regressed {plc} -> {leaf_count} without a "
                f"rebuild barrier")
        n = min(plc, leaf_count)
        if (ver[:n] < pv[:n]).any():
            bad("leaf version regressed on a surviving leaf (§4.2 "
                "monotonicity)")

    # ---- 6: inner levels ----
    levels = []
    for li, lv in enumerate(a.levels):
        levels.append(dict(
            knum=np.asarray(lv.knum), children=np.asarray(lv.children),
            anchors=np.asarray(lv.anchors), plen=np.asarray(lv.plen),
            prefix=np.asarray(lv.prefix),
            features=np.asarray(lv.features), count=int(lv.count)))
    for li, lv in enumerate(levels):
        cap = cfg.level_caps[li]
        cnt = lv["count"]
        if not (1 <= cnt <= cap):
            bad(f"level {li}: count {cnt} outside [1, cap={cap}]")
            lv["count"] = cnt = max(1, min(cnt, cap))
        child_hi = (levels[li + 1]["count"] if li + 1 < len(levels)
                    else leaf_count)
        for r in range(cnt):
            k = int(lv["knum"][r])
            if not (1 <= k <= cfg.ns):
                bad(f"level {li} node {r}: knum {k} outside [1, ns]")
                continue
            ch = lv["children"][r]
            an = lv["anchors"][r]
            if (ch[:k] == _EMPTY).any() or (an[:k] == _EMPTY).any():
                bad(f"level {li} node {r}: EMPTY child/anchor in a valid "
                    f"lane")
                continue
            if (ch[:k] < 0).any() or (ch[:k] >= child_hi).any():
                bad(f"level {li} node {r}: child id outside "
                    f"[0, {child_hi})")
            if (an[:k] < 0).any() or (an[:k] >= max(kc, 1)).any():
                bad(f"level {li} node {r}: anchor key id outside the "
                    f"pool watermark")
                continue
            ar = ranks[an[:k]]
            if k > 1 and not (np.diff(ar) > 0).all():
                bad(f"level {li} node {r}: anchors not strictly "
                    f"ascending")

    # ---- 7: DFS reachability + bounds ----
    reached = [set() for _ in levels]
    leaf_seq: List[int] = []
    leaf_bounds = {}
    dup_reach = False

    def walk(li: int, node: int, lo, hi):
        nonlocal dup_reach
        lv = levels[li]
        if not (0 <= node < lv["count"]):
            return
        if node in reached[li]:
            dup_reach = True
            return
        reached[li].add(node)
        k = int(lv["knum"][r0 := node])
        k = max(0, min(k, cfg.ns))
        ch = lv["children"][r0]
        an = lv["anchors"][r0]
        for i in range(k):
            c = int(ch[i])
            if c == _EMPTY:
                continue
            aid = int(an[i])
            a_rank = (int(ranks[aid]) if 0 <= aid < max(kc, 1) else None)
            clo = a_rank if i > 0 else lo
            nid = int(an[i + 1]) if i + 1 < k else _EMPTY
            chi = (int(ranks[nid]) if (i + 1 < k
                                       and 0 <= nid < max(kc, 1)) else hi)
            if li + 1 < len(levels):
                walk(li + 1, c, clo, chi)
            else:
                if 0 <= c < leaf_count and c not in leaf_bounds:
                    leaf_bounds[c] = (clo, chi)
                    leaf_seq.append(c)
                elif c in leaf_bounds:
                    dup_reach = True

    walk(0, 0, None, None)
    if dup_reach:
        bad("a node or leaf is reachable twice from the root")
    for li, lv in enumerate(levels):
        if len(reached[li]) != lv["count"]:
            bad(f"level {li}: DFS reaches {len(reached[li])} of "
                f"{lv['count']} allocated nodes")
    if leaf_seq != chain:
        bad("DFS leaf order differs from the sibling chain order")
    for c, (lo, hi) in leaf_bounds.items():
        sr = slot_rank[c][slot_rank[c] >= 0]
        if sr.size == 0:
            continue
        if lo is not None and int(sr.min()) < lo:
            bad(f"leaf {c}: live key below its anchor lower bound")
        if hi is not None and int(sr.max()) >= hi:
            bad(f"leaf {c}: live key at/above its anchor upper bound")

    # ---- 8: derived inner metadata agrees with recompute ----
    if kc > 0 and not v:  # skip on earlier damage: meta of garbage anchors
        from .fbtree import recompute_inner_meta
        import jax.numpy as jnp
        jkb = a.key_bytes
        jkl = a.key_lens
        for li, lv in enumerate(a.levels):
            cnt = levels[li]["count"]
            pl, pf, ft = recompute_inner_meta(jkb, jkl, lv.anchors,
                                              lv.knum, cfg.fs)
            if (not np.array_equal(np.asarray(pl)[:cnt],
                                   levels[li]["plen"][:cnt])
                    or not np.array_equal(np.asarray(pf)[:cnt],
                                          levels[li]["prefix"][:cnt])
                    or not np.array_equal(np.asarray(ft)[:cnt],
                                          levels[li]["features"][:cnt])):
                bad(f"level {li}: plen/prefix/features disagree with "
                    f"recompute_inner_meta of the anchors")

    # ---- 9: stacked/tuple layout coherence ----
    st = stack_levels(a.levels)
    for f in st._fields:
        if not np.array_equal(np.asarray(getattr(st, f)),
                              np.asarray(getattr(a.stacked, f))):
            bad(f"stacked layout field {f!r} out of sync with levels")
            break

    return FsckReport(ok=not v, violations=tuple(v), n_live=n_live,
                      n_leaves=leaf_count)


def check_sharded(st, prev=None, max_violations: int = 20) -> FsckReport:
    """fsck a ShardedTree: per-shard :func:`check_tree` plus the router
    invariants — ascending split keys and every shard's live keys inside
    its routed range."""
    v: List[str] = []
    n_live = 0
    n_leaves = 0
    prev_shards = getattr(prev, "shards", None)
    for s, t in enumerate(st.shards):
        p = (prev_shards[s] if prev_shards is not None
             and len(prev_shards) == len(st.shards) else None)
        rep = check_tree(t, name=f"shard{s}", prev=p,
                         max_violations=max_violations - len(v))
        v.extend(rep.violations)
        n_live += rep.n_live
        n_leaves += rep.n_leaves
    # router: ascending splits, and range partition holds
    sb = np.asarray(st.router.split_bytes)
    sl = np.asarray(st.router.split_lens)
    ranks = _key_ranks(sb, sl)
    if len(v) < max_violations:
        if sb.shape[0] != len(st.shards):
            v.append(f"router has {sb.shape[0]} splits for "
                     f"{len(st.shards)} shards")
        elif sb.shape[0] > 1 and not (np.diff(ranks) > 0).all():
            v.append("router split keys not strictly ascending")
    for s, t in enumerate(st.shards):
        if len(v) >= max_violations:
            break
        a = t.arrays
        occ = np.asarray(a.leaf_occ)
        kid = np.asarray(a.leaf_keyid)
        kc = int(a.key_count)
        ids = kid[occ]
        ids = ids[(ids >= 0) & (ids < kc)]
        if ids.size == 0:
            continue
        kb = np.asarray(a.key_bytes)[ids]
        kl = np.asarray(a.key_lens)[ids]
        # owner per live key via the same rank trick over keys + splits
        allb = np.concatenate([sb, kb], axis=0)
        alll = np.concatenate([sl, kl], axis=0)
        r = _key_ranks(allb, alll)
        split_r, key_r = r[:sb.shape[0]], r[sb.shape[0]:]
        owner = np.maximum(
            (key_r[:, None] >= split_r[None, :]).sum(axis=1) - 1, 0)
        if (owner != s).any():
            v.append(f"shard{s}: {int((owner != s).sum())} live keys "
                     f"route to a different shard (partition broken)")
    return FsckReport(ok=not v, violations=tuple(v), n_live=n_live,
                      n_leaves=n_leaves)


def check(obj, prev=None, max_violations: int = 20) -> FsckReport:
    """Dispatch on tree flavor (FBTree vs ShardedTree, duck-typed)."""
    if hasattr(obj, "shards"):
        return check_sharded(obj, prev=prev, max_violations=max_violations)
    return check_tree(obj, prev=prev, max_violations=max_violations)


def assert_ok(obj, prev=None, context: str = ""):
    """Raise ``AssertionError`` listing the violations (chaos/CI helper)."""
    rep = check(obj, prev=prev)
    if not rep.ok:
        where = f" [{context}]" if context else ""
        raise AssertionError(
            f"fsck failed{where}: " + "; ".join(rep.violations))
    return rep
