"""Unified traversal engine with pluggable branch *and* descent backends.

Branch resolution — prefix compare + feature comparison + suffix binary
search (paper §3.2–3.4) — is one reusable primitive applied identically at
every inner level. This module is the single entry point for all
root-to-leaf descent. Two backend kinds live in two registries
(DESIGN.md §3):

* **Level backends** resolve ONE inner level for a batch:
  ``fn(level, key_bytes, key_lens, node_ids, qb, ql, collect_stats=...)
  -> (child_ids, stats | None)``. Built-ins:
    - ``"jnp"``            pure-XLA oracle (``core.branch.branch_level``)
    - ``"pallas"``         Pallas feature-comparison kernel
                           (``kernels.feature_branch``; interpret mode
                           off-TPU, hardware kernel on TPU)
    - ``"binary"``         classic full-key binary search baseline
    - ``"binary+prefix"``  baseline with prefix skip
  The engine loops them over levels in either layout: ``"tuple"`` unrolls a
  Python loop over the per-level tuple, ``"stacked"`` runs one ``lax.scan``
  over the padded ``[n_levels, C_max, ...]`` Level pytree.

* **Descent backends** resolve the WHOLE root→leaf descent in one call —
  they receive the tree (stacked levels + key pool + leaf arrays) and the
  query batch, and own the per-level loop themselves:
  ``fn(tree, qb, ql, sibling_check=..., collect_stats=...)
  -> (leaf_ids, path, stats | None)``. Built-in: ``"fused"``
  (``kernels.fused_descent`` — one pallas_call keeps the descent resident
  on-core instead of relaunching a kernel per level). A descent backend may
  also expose a fused traverse+probe entry (the hashtag leaf probe as the
  kernel epilogue); ``core.batch_ops`` uses it to collapse descend+probe
  into one launch. Descent backends always consume ``arrays.stacked``, so
  the engine's ``layout`` field is ignored for them.

A third registry holds **scan backends** (DESIGN.md §6): whole-range-scan
kernels ``fn(tree, qb, ql, max_items=..., collect_stats=...)
-> (out_kid, out_val, emitted, rearranged)`` that own descent, sibling hop,
and the leaf-chain walk in one launch. ``core.batch_ops.range_scan``
dispatches through :meth:`TraversalEngine.scan_path`: engines whose backend
registers a scan entry (built-in: ``"fused"`` → ``kernels.fused_scan``)
collapse the scan into that kernel; every other backend falls back to the
jnp chain-walk reference in ``batch_ops`` (which still descends through the
engine's own backend).

``TraversalEngine`` is a frozen (hashable) dataclass so it can ride along
as a static jit argument; one engine value == one compiled specialization.
Its static ``collect_stats`` flag is threaded into every backend: with it
off, none of the ``BranchStats`` counter arithmetic is traced (the engine
returns zeros) while leaf ids and paths stay bit-identical — the
stats-free hot path serving and throughput benchmarks run on.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Dict, List, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from .branch import BranchStats, branch_level, to_sibling
from .fbtree import FBTree, Level

__all__ = [
    "TraversalEngine", "DEFAULT_ENGINE", "DescentBackend", "ScanBackend",
    "register_backend", "get_backend", "register_descent_backend",
    "get_descent_backend", "register_scan_backend", "get_scan_backend",
    "available_backends", "backend_kind", "resolve_engine",
]

# fn(level, key_bytes, key_lens, node_ids, qb, ql, collect_stats=...)
#   -> (child_ids, stats | None)
BackendFn = Callable[..., Tuple[jnp.ndarray, Optional[BranchStats]]]

_BACKENDS: Dict[str, BackendFn] = {}
_LAZY_BACKENDS: Dict[str, Callable[[], BackendFn]] = {}


class DescentBackend(NamedTuple):
    """A whole-descent backend (DESIGN.md §3).

    ``traverse(tree, qb, ql, sibling_check=..., collect_stats=...)``
      -> (leaf_ids, path, stats | None) — ``path[l]`` is each query's node
      id at level ``l``, matching ``TraversalEngine.traverse``.
    ``traverse_probe`` (optional) additionally fuses the hashtag leaf probe
      as the epilogue: ``(tree, qb, ql, sibling_check=..., collect_stats=...)
      -> (leaf_ids, path, found, slot, val, bstats | None, lstats | None)``.
    """
    traverse: Callable
    traverse_probe: Optional[Callable] = None


_DESCENT: Dict[str, DescentBackend] = {}
_LAZY_DESCENT: Dict[str, Callable[[], DescentBackend]] = {}

# fn(tree, qb, ql, max_items=..., collect_stats=...)
#   -> (out_kid [B, max_items], out_val [B, max_items], emitted [B],
#       rearranged [B]) — the ``core.batch_ops.range_scan`` contract
# (DESIGN.md §6). ``rearranged`` must be all-zero (and untraced) when
# ``collect_stats`` is off.
ScanBackend = Callable[..., Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray,
                                  jnp.ndarray]]

_SCAN: Dict[str, ScanBackend] = {}
_LAZY_SCAN: Dict[str, Callable[[], ScanBackend]] = {}


def register_backend(name: str, fn: BackendFn = None, *,
                     loader: Callable[[], BackendFn] = None) -> None:
    """Register a per-level branch backend (eagerly, or via a deferred
    ``loader`` for backends whose import is heavy or optional)."""
    assert (fn is None) != (loader is None), "pass exactly one of fn/loader"
    if fn is not None:
        _BACKENDS[name] = fn
        _LAZY_BACKENDS.pop(name, None)
    else:
        _LAZY_BACKENDS[name] = loader


def register_descent_backend(name: str, backend: DescentBackend = None, *,
                             loader: Callable[[], DescentBackend] = None,
                             ) -> None:
    """Register a whole-descent backend (same eager/lazy split as
    :func:`register_backend`)."""
    assert (backend is None) != (loader is None), \
        "pass exactly one of backend/loader"
    if backend is not None:
        _DESCENT[name] = backend
        _LAZY_DESCENT.pop(name, None)
    else:
        _LAZY_DESCENT[name] = loader


def register_scan_backend(name: str, fn: ScanBackend = None, *,
                          loader: Callable[[], ScanBackend] = None) -> None:
    """Register a whole-scan backend (same eager/lazy split as
    :func:`register_backend`). A scan backend rides under the same name as
    the level/descent backend it pairs with (e.g. ``"fused"`` registers
    both a descent and a scan entry); ``range_scan`` dispatches to it via
    :meth:`TraversalEngine.scan_path` (DESIGN.md §6)."""
    assert (fn is None) != (loader is None), "pass exactly one of fn/loader"
    if fn is not None:
        _SCAN[name] = fn
        _LAZY_SCAN.pop(name, None)
    else:
        _LAZY_SCAN[name] = loader


def get_backend(name: str) -> BackendFn:
    if name not in _BACKENDS:
        if name not in _LAZY_BACKENDS:
            raise KeyError(
                f"unknown level backend {name!r}; "
                f"available: {available_backends()}")
        _BACKENDS[name] = _LAZY_BACKENDS.pop(name)()
    return _BACKENDS[name]


def get_descent_backend(name: str) -> DescentBackend:
    if name not in _DESCENT:
        if name not in _LAZY_DESCENT:
            raise KeyError(
                f"unknown descent backend {name!r}; "
                f"available: {available_backends()}")
        _DESCENT[name] = _LAZY_DESCENT.pop(name)()
    return _DESCENT[name]


def get_scan_backend(name: str) -> ScanBackend:
    if name not in _SCAN:
        if name not in _LAZY_SCAN:
            raise KeyError(
                f"unknown scan backend {name!r}; "
                f"available: {available_backends()}")
        _SCAN[name] = _LAZY_SCAN.pop(name)()
    return _SCAN[name]


def available_backends() -> List[str]:
    return sorted(set(_BACKENDS) | set(_LAZY_BACKENDS)
                  | set(_DESCENT) | set(_LAZY_DESCENT)
                  | set(_SCAN) | set(_LAZY_SCAN))


def backend_kind(name: str) -> str:
    """``"level"``, ``"descent"``, or ``"scan"`` for a scan-only name
    (KeyError if unregistered). Names registered in several registries
    report the kind that drives point-op descent: descent > level."""
    if name in _DESCENT or name in _LAZY_DESCENT:
        return "descent"
    if name in _BACKENDS or name in _LAZY_BACKENDS:
        return "level"
    if name in _SCAN or name in _LAZY_SCAN:
        return "scan"
    raise KeyError(f"unknown traversal backend {name!r}; "
                   f"available: {available_backends()}")


def _load_pallas_backend() -> BackendFn:
    from repro.kernels.feature_branch.ops import branch_level_pallas
    return branch_level_pallas


def _load_binary_backend(use_prefix: bool) -> BackendFn:
    from .baseline import branch_level_binary
    return functools.partial(branch_level_binary, use_prefix=use_prefix)


def _load_fused_backend() -> DescentBackend:
    from repro.kernels.fused_descent.ops import (fused_traverse,
                                                 fused_traverse_probe)
    return DescentBackend(fused_traverse, fused_traverse_probe)


def _load_fused_scan_backend() -> ScanBackend:
    from repro.kernels.fused_scan.ops import fused_range_scan
    return fused_range_scan


register_backend("jnp", branch_level)
register_backend("pallas", loader=_load_pallas_backend)
register_backend("binary", loader=functools.partial(_load_binary_backend, False))
register_backend("binary+prefix",
                 loader=functools.partial(_load_binary_backend, True))
register_descent_backend("fused", loader=_load_fused_backend)
register_scan_backend("fused", loader=_load_fused_scan_backend)

LAYOUTS = ("tuple", "stacked")


@dataclasses.dataclass(frozen=True)
class TraversalEngine:
    """Root-to-leaf descent strategy: (backend, layout, collect_stats).

    ``layout=None`` defers to ``tree.config.stacked`` at trace time, so one
    engine value serves trees of either default layout (descent backends
    ignore layout — they always consume the stacked pytree).
    ``collect_stats=False`` compiles the stats machinery to nothing: the
    returned ``BranchStats`` are all-zero, leaf ids/paths bit-identical.
    """
    backend: str = "jnp"
    layout: Optional[str] = None
    collect_stats: bool = True

    def __post_init__(self):
        # fail at construction, not deep inside the first jit trace
        if self.backend not in available_backends():
            raise ValueError(f"unknown traversal backend {self.backend!r}; "
                             f"available: {available_backends()}")
        if self.layout not in (None,) + LAYOUTS:
            raise ValueError(f"unknown layout {self.layout!r}; "
                             f"expected one of {LAYOUTS} or None")

    @property
    def kind(self) -> str:
        return backend_kind(self.backend)

    def resolve_layout(self, tree: FBTree) -> str:
        return self.layout or ("stacked" if tree.config.stacked else "tuple")

    def probe_path(self) -> Optional[Callable]:
        """Fused traverse+probe entry of a descent backend, or None — the
        hook ``core.batch_ops._traverse_probe`` collapses to one launch."""
        if self.kind != "descent":
            return None
        return get_descent_backend(self.backend).traverse_probe

    def scan_path(self) -> Optional[ScanBackend]:
        """Whole-scan kernel entry of this engine's backend, or None —
        ``core.batch_ops.range_scan`` collapses the scan to one launch when
        present, and otherwise runs the jnp chain-walk reference (which
        still descends through this engine's backend). DESIGN.md §6."""
        if self.backend in _SCAN or self.backend in _LAZY_SCAN:
            return get_scan_backend(self.backend)
        return None

    def traverse(self, tree: FBTree, qb: jnp.ndarray, ql: jnp.ndarray,
                 sibling_check: bool = True,
                 ) -> Tuple[jnp.ndarray, List[jnp.ndarray], BranchStats]:
        """Descend all inner levels. Returns (leaf_ids, path, stats) where
        ``path[l]`` is each query's node id AT level ``l`` (root first) —
        the parent chain the split path propagates anchors through."""
        B = qb.shape[0]
        cs = self.collect_stats

        if self.kind == "descent":
            d = get_descent_backend(self.backend)
            leaf_ids, path, stats = d.traverse(
                tree, qb, ql, sibling_check=sibling_check, collect_stats=cs)
            return leaf_ids, path, stats if cs else BranchStats.zeros(B)

        a = tree.arrays
        fn = get_backend(self.backend)
        node_ids = jnp.zeros((B,), jnp.int32)   # root = node 0 of level 0
        stats = BranchStats.zeros(B)

        if self.resolve_layout(tree) == "tuple":
            path = []
            for level in a.levels:
                path.append(node_ids)
                node_ids, s = fn(level, a.key_bytes, a.key_lens, node_ids,
                                 qb, ql, collect_stats=cs)
                if cs:
                    stats = stats + s
        elif cs:
            def step(carry, level: Level):
                ids, st = carry
                child, s = fn(level, a.key_bytes, a.key_lens, ids, qb, ql,
                              collect_stats=True)
                return (child, st + s), ids
            (node_ids, stats), path_arr = jax.lax.scan(
                step, (node_ids, stats), a.stacked)
            path = [path_arr[l] for l in range(len(a.levels))]
        else:
            # stats-free scan: the carry is just the node ids — the stats
            # pytree never enters the compiled loop at all
            def step(ids, level: Level):
                child, _ = fn(level, a.key_bytes, a.key_lens, ids, qb, ql,
                              collect_stats=False)
                return child, ids
            node_ids, path_arr = jax.lax.scan(step, node_ids, a.stacked)
            path = [path_arr[l] for l in range(len(a.levels))]

        if sibling_check:
            node_ids, hops = to_sibling(tree, node_ids, qb, ql)
            if cs:
                stats = stats._replace(
                    sibling_hops=stats.sibling_hops + hops)
        return node_ids, path, stats


DEFAULT_ENGINE = TraversalEngine(backend="jnp", layout=None)


def resolve_engine(engine: Optional[TraversalEngine]) -> TraversalEngine:
    return DEFAULT_ENGINE if engine is None else engine
