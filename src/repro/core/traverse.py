"""Unified scan-based traversal engine with pluggable branch backends.

Branch resolution — prefix compare + feature comparison + suffix binary
search (paper §3.2–3.4) — is one reusable primitive applied identically at
every inner level. This module is the single entry point for all
root-to-leaf descent:

* **Backend registry** maps a name to a ``branch_level``-shaped function
  ``fn(level, key_bytes, key_lens, node_ids, qb, ql) -> (child_ids, stats)``.
  Built-ins:
    - ``"jnp"``            pure-XLA oracle (``core.branch.branch_level``)
    - ``"pallas"``         Pallas feature-comparison kernel
                           (``kernels.feature_branch``; interpret mode
                           off-TPU, hardware kernel on TPU)
    - ``"binary"``         classic full-key binary search baseline
    - ``"binary+prefix"``  baseline with prefix skip
  New kernels land here via :func:`register_backend` without touching op
  code.

* **Layouts**: ``"tuple"`` descends the per-level tuple with an unrolled
  Python loop (one XLA op chain per level — levels may have different node
  counts). ``"stacked"`` runs one ``lax.scan`` over the padded
  ``[n_levels, C_max, ...]`` Level pytree (level-synchronous batched
  traversal over homogeneous node arrays, BS-tree style): the compiled
  module carries a single level-step body regardless of tree height, and
  ``BranchStats`` accumulate inside the scan carry.

``TraversalEngine`` is a frozen (hashable) dataclass so it can ride along
as a static jit argument; one engine value == one compiled specialization.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from .branch import BranchStats, branch_level, to_sibling
from .fbtree import FBTree, Level

__all__ = [
    "TraversalEngine", "DEFAULT_ENGINE", "register_backend", "get_backend",
    "available_backends", "resolve_engine",
]

# fn(level, key_bytes, key_lens, node_ids, qb, ql) -> (child_ids, stats)
BackendFn = Callable[..., Tuple[jnp.ndarray, BranchStats]]

_BACKENDS: Dict[str, BackendFn] = {}
_LAZY_BACKENDS: Dict[str, Callable[[], BackendFn]] = {}


def register_backend(name: str, fn: BackendFn = None, *,
                     loader: Callable[[], BackendFn] = None) -> None:
    """Register a branch backend (eagerly, or via a deferred ``loader`` for
    backends whose import is heavy or optional)."""
    assert (fn is None) != (loader is None), "pass exactly one of fn/loader"
    if fn is not None:
        _BACKENDS[name] = fn
        _LAZY_BACKENDS.pop(name, None)
    else:
        _LAZY_BACKENDS[name] = loader


def get_backend(name: str) -> BackendFn:
    if name not in _BACKENDS:
        if name not in _LAZY_BACKENDS:
            raise KeyError(
                f"unknown traversal backend {name!r}; "
                f"available: {sorted(set(_BACKENDS) | set(_LAZY_BACKENDS))}")
        _BACKENDS[name] = _LAZY_BACKENDS.pop(name)()
    return _BACKENDS[name]


def available_backends() -> List[str]:
    return sorted(set(_BACKENDS) | set(_LAZY_BACKENDS))


def _load_pallas_backend() -> BackendFn:
    from repro.kernels.feature_branch.ops import branch_level_pallas
    return branch_level_pallas


def _load_binary_backend(use_prefix: bool) -> BackendFn:
    from .baseline import branch_level_binary
    return functools.partial(branch_level_binary, use_prefix=use_prefix)


register_backend("jnp", branch_level)
register_backend("pallas", loader=_load_pallas_backend)
register_backend("binary", loader=functools.partial(_load_binary_backend, False))
register_backend("binary+prefix",
                 loader=functools.partial(_load_binary_backend, True))

LAYOUTS = ("tuple", "stacked")


@dataclasses.dataclass(frozen=True)
class TraversalEngine:
    """Root-to-leaf descent strategy: (backend, layout).

    ``layout=None`` defers to ``tree.config.stacked`` at trace time, so one
    engine value serves trees of either default layout.
    """
    backend: str = "jnp"
    layout: Optional[str] = None

    def __post_init__(self):
        # fail at construction, not deep inside the first jit trace
        if self.backend not in available_backends():
            raise ValueError(f"unknown traversal backend {self.backend!r}; "
                             f"available: {available_backends()}")
        if self.layout not in (None,) + LAYOUTS:
            raise ValueError(f"unknown layout {self.layout!r}; "
                             f"expected one of {LAYOUTS} or None")

    def resolve_layout(self, tree: FBTree) -> str:
        return self.layout or ("stacked" if tree.config.stacked else "tuple")

    def traverse(self, tree: FBTree, qb: jnp.ndarray, ql: jnp.ndarray,
                 sibling_check: bool = True,
                 ) -> Tuple[jnp.ndarray, List[jnp.ndarray], BranchStats]:
        """Descend all inner levels. Returns (leaf_ids, path, stats) where
        ``path[l]`` is each query's node id AT level ``l`` (root first) —
        the parent chain the split path propagates anchors through."""
        a = tree.arrays
        fn = get_backend(self.backend)
        B = qb.shape[0]
        node_ids = jnp.zeros((B,), jnp.int32)   # root = node 0 of level 0
        stats = BranchStats.zeros(B)

        if self.resolve_layout(tree) == "tuple":
            path = []
            for level in a.levels:
                path.append(node_ids)
                node_ids, s = fn(level, a.key_bytes, a.key_lens, node_ids,
                                 qb, ql)
                stats = stats + s
        else:
            def step(carry, level: Level):
                ids, st = carry
                child, s = fn(level, a.key_bytes, a.key_lens, ids, qb, ql)
                return (child, st + s), ids
            (node_ids, stats), path_arr = jax.lax.scan(
                step, (node_ids, stats), a.stacked)
            path = [path_arr[l] for l in range(len(a.levels))]

        if sibling_check:
            node_ids, hops = to_sibling(tree, node_ids, qb, ql)
            stats = stats._replace(sibling_hops=stats.sibling_hops + hops)
        return node_ids, path, stats


DEFAULT_ENGINE = TraversalEngine(backend="jnp", layout=None)


def resolve_engine(engine: Optional[TraversalEngine]) -> TraversalEngine:
    return DEFAULT_ENGINE if engine is None else engine
