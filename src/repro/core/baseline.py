"""Baseline B+-tree branch/probe variants for the paper's factor analysis.

Fig. 12(a) enables optimizations one by one starting from a typical B+-tree:

  base       binary search over anchors in inner nodes + binary search in
             sorted leaves (STX-B+-tree / B+-treeOLC behaviour)
  +prefix    compare the common prefix once, then binary search on suffixes
  +feature2  feature comparison with fs=2 (build the tree with fs=2)
  +feature4  feature comparison with fs=4 (the default engine)
  +hashtag   hashtag probe in leaves instead of leaf binary search

All variants run over the same FBTree arrays so throughput and the modeled
hardware counters (key compares, 64B lines touched) are directly comparable.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from .branch import BranchStats
from .fbtree import FBTree, Level
from .keys import compare_padded
from .leaf import LeafStats, probe
from .traverse import TraversalEngine, resolve_engine

__all__ = ["branch_level_binary", "probe_leaf_binary", "lookup_variant",
           "VARIANTS"]

VARIANTS = ("base", "prefix", "feature", "feature+hash")


def _full_cmp(key_bytes, key_lens, aid, qb, ql, skip: jnp.ndarray = None):
    aid_safe = jnp.maximum(aid, 0)
    akb = key_bytes[aid_safe]
    akl = key_lens[aid_safe]
    return compare_padded(akb, akl, qb, ql)  # anchor vs query


def branch_level_binary(level: Level, key_bytes, key_lens, node_ids, qb, ql,
                        use_prefix: bool, collect_stats: bool = True,
                        ) -> Tuple[jnp.ndarray, Optional[BranchStats]]:
    """Classic binary-search branch (optionally with +prefix suffix skip)."""
    B = node_ids.shape[0]
    ns = level.features.shape[-1]
    knum = level.knum[node_ids]
    plen = level.plen[node_ids]
    anchors = level.anchors[node_ids]

    if use_prefix:
        # one prefix compare, counted as touching the prefix line(s)
        prefix = level.prefix[node_ids]
        L = qb.shape[-1]
        pos = jnp.arange(L, dtype=jnp.int32)
        m = pos[None, :] < plen[:, None]
        diff = (qb.astype(jnp.int32) - prefix.astype(jnp.int32)) * m
        nz = diff != 0
        anynz = nz.any(-1)
        fi = jnp.argmax(nz, axis=-1)
        first = jnp.take_along_axis(diff, fi[:, None], axis=-1)[:, 0]
        pcmp = jnp.where(anynz, jnp.sign(first), 0).astype(jnp.int32)
    else:
        pcmp = jnp.zeros((B,), jnp.int32)

    lo = jnp.zeros((B,), jnp.int32)
    hi = knum
    key_cmp = jnp.zeros((B,), jnp.int32)
    n_steps = max(1, ns.bit_length())
    for _ in range(n_steps):
        active = lo < hi
        mid = jnp.clip((lo + hi) // 2, 0, ns - 1)
        aid = jnp.take_along_axis(anchors, mid[:, None], axis=-1)[:, 0]
        c = _full_cmp(key_bytes, key_lens, aid, qb, ql)
        go_right = c <= 0
        lo = jnp.where(active & go_right, mid + 1, lo)
        hi = jnp.where(active & ~go_right, mid, hi)
        if collect_stats:
            key_cmp = key_cmp + active.astype(jnp.int32)
    idx = jnp.clip(lo - 1, 0, jnp.maximum(knum - 1, 0))
    idx = jnp.where(pcmp < 0, 0, idx)
    idx = jnp.where(pcmp > 0, jnp.maximum(knum - 1, 0), idx)
    trivial = knum <= 1
    idx = jnp.where(trivial, 0, idx)
    child = jnp.take_along_axis(level.children[node_ids], idx[:, None], axis=-1)[:, 0]

    if not collect_stats:
        return child, None
    # modeled lines: control line + per compare (anchor-pointer line + key
    # line(s)); +prefix adds the prefix line but shortens the compared bytes.
    nzs = lambda x: jnp.where(trivial, 0, x).astype(jnp.int32)
    cmp_bytes = jnp.maximum(ql - (plen if use_prefix else 0), 1)
    kw_lines = (cmp_bytes + 63) // 64
    lines = 1 + key_cmp * (1 + kw_lines) + (1 if use_prefix else 0) + 1
    stats = BranchStats(
        feat_rounds=jnp.zeros((B,), jnp.int32),
        suffix_bs=nzs(jnp.ones((B,), jnp.int32)),
        key_compares=nzs(key_cmp),
        lines_touched=nzs(lines),
        sibling_hops=jnp.zeros((B,), jnp.int32),
    )
    return child, stats


def probe_leaf_binary(tree: FBTree, leaf_ids, qb, ql):
    """Sorted-leaf binary search (models STX; requires bulk-built leaves)."""
    a = tree.arrays
    ns = a.leaf_tags.shape[-1]
    B = leaf_ids.shape[0]
    occ = a.leaf_occ[leaf_ids]
    kid = a.leaf_keyid[leaf_ids]
    nocc = occ.sum(-1).astype(jnp.int32)
    lo = jnp.zeros((B,), jnp.int32)
    hi = nocc
    key_cmp = jnp.zeros((B,), jnp.int32)
    for _ in range(max(1, ns.bit_length())):
        active = lo < hi
        mid = jnp.clip((lo + hi) // 2, 0, ns - 1)
        aid = jnp.take_along_axis(kid, mid[:, None], axis=-1)[:, 0]
        c = _full_cmp(a.key_bytes, a.key_lens, aid, qb, ql)
        go_right = c < 0
        lo = jnp.where(active & go_right, mid + 1, lo)
        hi = jnp.where(active & ~go_right, mid, hi)
        key_cmp = key_cmp + active.astype(jnp.int32)
    slot = jnp.clip(lo, 0, ns - 1)
    aid = jnp.take_along_axis(kid, slot[:, None], axis=-1)[:, 0]
    c = _full_cmp(a.key_bytes, a.key_lens, aid, qb, ql)
    in_range = lo < nocc
    found = in_range & (c == 0)
    val = jnp.take_along_axis(a.leaf_val[leaf_ids], slot[:, None], axis=-1)[:, 0]
    val = jnp.where(found, val, 0)
    kw_lines = (ql + 63) // 64
    stats = LeafStats(
        tag_candidates=jnp.zeros((B,), jnp.int32),
        lines_touched=(1 + (key_cmp + 1) * (1 + kw_lines)).astype(jnp.int32),
    )
    return found, slot, val, stats


@functools.partial(jax.jit, static_argnames=("variant", "engine"))
def lookup_variant(tree: FBTree, qb, ql, variant: str = "feature+hash",
                   engine: Optional[TraversalEngine] = None):
    """Point lookup under a factor-analysis variant. Returns (found, val, stats).

    All variants descend through the traversal engine: the binary-search
    baselines are the registered ``binary`` / ``binary+prefix`` backends,
    and the feature variants use ``engine``'s backend (``jnp`` or
    ``pallas``). ``engine`` also selects the descent layout.
    """
    assert variant in VARIANTS, variant
    eng = resolve_engine(engine)
    if variant in ("base", "prefix"):
        eng = TraversalEngine(
            backend="binary" if variant == "base" else "binary+prefix",
            layout=eng.layout, collect_stats=eng.collect_stats)
    node_ids, _, stats = eng.traverse(tree, qb, ql, sibling_check=True)
    if variant == "feature+hash":
        found, slot, val, ls = probe(tree, node_ids, qb, ql,
                                     collect_stats=eng.collect_stats)
    else:
        found, slot, val, ls = probe_leaf_binary(tree, node_ids, qb, ql)
    if ls is None:
        ls = LeafStats.zeros(node_ids.shape[0])
    return found, val, stats._replace(
        lines_touched=stats.lines_touched + ls.lines_touched), ls
