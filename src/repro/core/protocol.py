"""Instruction-interleaved simulator of the paper's §4 synchronization protocol.

The paper's concurrency claims (latch-free update via CAS, optimistic version
validation, Blink-style splits with `splitting` bit and cross-node tracking)
are shared-memory-thread semantics with no analogue inside a single SPMD TPU
step (DESIGN.md §2). This module validates them *literally*: every shared
memory access is an atomic step of a coroutine, and a scheduler interleaves
coroutines arbitrarily. Hypothesis drives schedules in tests and checks
linearizability-style invariants.

Implemented faithfully from the paper:
  * control word per node: version | splitting | ordered | locked | deleted
    (Fig. 7); insert/remove bump the version, update does NOT (§4.2);
  * optimistic reads: begin_read / end_read validation loop (Fig. 8);
  * latch-free update: read slot -> CAS(kv, old, new); on failure re-validate
    version, check high_key, hop to sibling or retry (§4.4, Fig. 9/10);
  * kv migration during split uses ATOMIC_EXCHANGE(slot, None) so concurrent
    CAS updates fail and chase the sibling pointer (§4.4);
  * insert: lock leaf; full leaf -> set splitting, move upper half to new
    sibling, link, lock parent, insert anchor, bump parent version, clear
    splitting (§4.2 structure modification).
"""
from __future__ import annotations

import random
from dataclasses import dataclass, field
from typing import Any, Dict, Generator, List, Optional, Tuple

__all__ = ["Sim", "Node", "run_schedule", "check_invariants"]

NS = 8  # small node size so schedules hit splits quickly


@dataclass
class Node:
    leaf: bool = True
    version: int = 0
    splitting: bool = False
    ordered: bool = True
    locked: bool = False
    deleted: bool = False
    # leaf payload: slot -> (key, val) or None  (kvs pointer array + bitmap)
    kvs: List[Optional[Tuple[Any, Any]]] = field(default_factory=lambda: [None] * NS)
    high_key: Any = None          # None = +inf
    next: Optional["Node"] = None


class Sim:
    """A two-level tree (root anchor table + leaf chain) with stepwise ops.

    Each public op returns a generator; every ``yield`` is a preemption point
    (the paper's unit of atomicity: one load / CAS / store).
    """

    def __init__(self, keys=(), seed: int = 0xFB):
        self.root_version = 0
        self.root_locked = False
        first = Node()
        self.anchors: List[Tuple[Any, Node]] = [(None, first)]  # sorted (low_key, node)
        self.log: List[Tuple] = []  # commit log: (op, key, val, info)
        # explicit seeded RNG: run_schedule's fallback scheduling draws from
        # it, so a failing hypothesis example replays deterministically from
        # (ops, schedule, seed) alone — no module-level random state
        self.rng = random.Random(seed)
        for k in sorted(keys):
            list(self.insert(k, ("init", k)))

    # ---- root helpers (anchor table guarded by root version/lock) ----
    def _locate(self, key) -> Node:
        node = self.anchors[0][1]
        for low, n in self.anchors:
            if low is None or (key is not None and key >= low):
                node = n
        return node

    # ---- control-word primitives ----
    def _begin_read(self, n: Node):
        return (n.version, n.splitting)

    def _end_read(self, n: Node, snap) -> bool:
        return (not n.locked) and n.version == snap[0]

    # ---------------- lookup (Fig. 8) ----------------
    def lookup(self, key) -> Generator:
        while True:
            node = self._locate(key)
            yield
            while True:
                snap = self._begin_read(node)
                yield
                # to_sibling: high-key check
                if node.high_key is not None and key >= node.high_key and node.next:
                    node = node.next
                    continue
                val = None
                for slot in range(NS):
                    kv = node.kvs[slot]          # atomic pointer load
                    if kv is not None and kv[0] == key:
                        val = kv[1]
                        break
                yield
                if val is not None:
                    # found: return immediately without validation (Fig. 8 L13)
                    self.log.append(("lookup", key, val, None))
                    return val
                if self._end_read(node, snap):
                    self.log.append(("lookup", key, None, None))
                    return None
                yield  # validation failed -> retry node

    # ---------------- latch-free update (§4.4) ----------------
    def update(self, key, new_val) -> Generator:
        while True:
            node = self._locate(key)
            yield
            retries = 0
            while True:
                snap = self._begin_read(node)
                yield
                if node.high_key is not None and key >= node.high_key and node.next:
                    node = node.next
                    continue
                slot_idx, old = None, None
                for slot in range(NS):
                    kv = node.kvs[slot]
                    if kv is not None and kv[0] == key:
                        slot_idx, old = slot, kv
                        break
                yield
                if slot_idx is not None:
                    # the only serialized step: CAS on the kv pointer
                    if node.kvs[slot_idx] is old:          # CAS succeeds
                        node.kvs[slot_idx] = (key, new_val)
                        self.log.append(("update", key, new_val, "ok"))
                        return True
                    yield  # CAS failed: kv exchanged (migration) or replaced
                    if node.version != snap[0]:
                        # moved by split/merge: re-check high key, chase sibling
                        continue
                    retries += 1
                    continue
                # not found in this node: only a validated snapshot (no lock
                # held, version unchanged, not splitting) proves real absence
                if self._end_read(node, snap) and not node.splitting:
                    self.log.append(("update", key, None, "miss"))
                    return False
                yield              # changed / mid-split: kv may have moved
                continue

    # ---------------- insert with split (§4.2) ----------------
    def insert(self, key, val) -> Generator:
        while True:
            node = self._locate(key)
            yield
            # acquire write lock (spin)
            while node.locked:
                yield
            node.locked = True
            yield
            # re-validate residence after locking
            if node.high_key is not None and key >= node.high_key and node.next:
                node.locked = False
                node = node.next
                continue
            if node.deleted:
                node.locked = False
                yield
                continue
            # existing key -> treat as update-under-lock
            for slot in range(NS):
                kv = node.kvs[slot]
                if kv is not None and kv[0] == key:
                    node.kvs[slot] = (key, val)
                    node.locked = False
                    self.log.append(("insert", key, val, "overwrite"))
                    return True
            free = [s for s in range(NS) if node.kvs[s] is None]
            if free:
                node.kvs[free[0]] = (key, val)
                node.version += 1          # insert bumps version (§4.2)
                node.locked = False
                self.log.append(("insert", key, val, "ok"))
                return True
            # ---- split: link technique ----
            node.splitting = True
            yield
            items = sorted(kv for kv in node.kvs if kv is not None)
            mid = len(items) // 2
            split_key = items[mid][0]
            new = Node()
            new.high_key = node.high_key
            new.next = node.next
            yield
            # migrate upper half: latest = ATOMIC_EXCHANGE(slot, NULL); install
            # latest into the new node (§4.4 — the exchange *obtains the latest
            # pointer*, so a racing CAS update either lands before the exchange
            # and is carried over, or observes NULL and chases the sibling)
            j = 0
            for s in range(NS):
                kv = node.kvs[s]
                if kv is not None and kv[0] >= split_key:
                    latest, node.kvs[s] = node.kvs[s], None  # atomic exchange
                    new.kvs[j] = latest
                    j += 1
                    yield
            node.high_key = split_key
            node.next = new
            node.version += 1
            yield
            # step (2): insert anchor into parent under parent lock
            while self.root_locked:
                yield
            self.root_locked = True
            yield
            self.anchors.append((split_key, new))
            self.anchors.sort(key=lambda t: (t[0] is not None, t[0]))
            self.root_version += 1
            self.root_locked = False
            node.splitting = False         # cross-node tracking end (§4.3)
            node.locked = False
            yield
            # retry the original insert (now guaranteed space somewhere)
            continue

    # ---------------- remove ----------------
    def remove(self, key) -> Generator:
        while True:
            node = self._locate(key)
            yield
            while node.locked:
                yield
            node.locked = True
            yield
            if node.high_key is not None and key >= node.high_key and node.next:
                node.locked = False
                node = node.next
                continue
            ok = False
            for slot in range(NS):
                kv = node.kvs[slot]
                if kv is not None and kv[0] == key:
                    node.kvs[slot] = None   # exchange
                    ok = True
                    break
            if ok:
                node.version += 1           # remove bumps version
            node.locked = False
            self.log.append(("remove", key, None, "ok" if ok else "miss"))
            return ok

    # ---- inspection ----
    def leaf_chain(self) -> List[Node]:
        out = []
        n = self.anchors[0][1]
        while n is not None:
            out.append(n)
            n = n.next
        return out

    def contents(self) -> Dict[Any, Any]:
        d = {}
        for n in self.leaf_chain():
            for kv in n.kvs:
                if kv is not None:
                    assert kv[0] not in d, "duplicate key across leaves"
                    d[kv[0]] = kv[1]
        return d


def run_schedule(sim: Sim, ops: List[Generator], schedule,
                 rng: Optional[random.Random] = None) -> None:
    """Interleave op coroutines. ``schedule`` yields indices into live ops
    (ints; modulo live count) — hypothesis supplies arbitrary schedules.

    Once the schedule is exhausted (or when it is ``None``) the remaining
    steps draw from ``rng`` — an explicit ``random.Random`` (or an int
    seed), defaulting to the simulator's own seeded ``sim.rng`` — so a
    replay of the same (ops, schedule, seed) triple is bit-for-bit
    deterministic."""
    live = list(ops)
    if rng is None:
        rnd = sim.rng
    elif isinstance(rng, int):
        rnd = random.Random(rng)
    else:
        rnd = rng
    it = iter(schedule) if schedule is not None else None
    guard = 0
    while live:
        guard += 1
        if guard > 200_000:
            raise RuntimeError("schedule did not terminate (livelock?)")
        if it is not None:
            try:
                i = next(it) % len(live)
            except StopIteration:
                it = None
                continue
        else:
            i = rnd.randrange(len(live))
        try:
            next(live[i])
        except StopIteration:
            live.pop(i)


def check_invariants(sim: Sim) -> None:
    """Post-quiescence invariants (linearizability-style)."""
    # 1. leaf chain strictly ordered and consistent with high keys
    chain = sim.leaf_chain()
    prev_max = None
    for n in chain:
        ks = sorted(kv[0] for kv in n.kvs if kv is not None)
        if ks:
            if prev_max is not None:
                assert ks[0] > prev_max, "chain order violated"
            prev_max = ks[-1]
        if n.high_key is not None:
            assert all(k < n.high_key for k in ks), "high_key violated"
    # 2. final value of each key equals the last committed write in the log
    expect: Dict[Any, Any] = {}
    for op, key, val, info in sim.log:
        if op == "insert" and info in ("ok", "overwrite"):
            expect[key] = val
        elif op == "update" and info == "ok":
            expect[key] = val
        elif op == "remove" and info == "ok":
            expect.pop(key, None)
    got = sim.contents()
    assert got == expect, f"lost/phantom updates: {got} != {expect}"
    # 3. every lookup returned a value some write actually installed
    writes: Dict[Any, set] = {}
    for op, key, val, info in sim.log:
        if op in ("insert", "update") and val is not None and info in (
                "ok", "overwrite"):
            writes.setdefault(key, set()).add(val)
    for op, key, val, _ in sim.log:
        if op == "lookup" and val is not None:
            assert val in writes.get(key, set()), "lookup returned garbage"
