"""FB+-tree core: the paper's data structure + batched latch-free ops in JAX."""
from .fbtree import FBTree, TreeConfig, bulk_build, stack_levels
from .keys import KeySet, make_keyset, encode_uint64, encode_int64
from .branch import traverse, branch_level, BranchStats
from .leaf import probe
from .traverse import (TraversalEngine, DEFAULT_ENGINE, register_backend,
                       available_backends)
from .batch_ops import (lookup_batch, update_batch, insert_batch, remove_batch,
                        range_scan, rebuild, traverse_probe, OpReport,
                        BuildReport)
from .baseline import lookup_variant, VARIANTS
from .fsck import FsckReport, check_tree
from .faults import (FaultInjected, ShardDropped, FaultSpec, FaultPlan,
                     RetryPolicy)
from .lifecycle import TreeVersionManager, PublishReport

__all__ = [
    "FBTree", "TreeConfig", "bulk_build", "stack_levels", "KeySet",
    "make_keyset", "encode_uint64", "encode_int64", "traverse", "branch_level",
    "BranchStats", "probe", "TraversalEngine", "DEFAULT_ENGINE",
    "register_backend", "available_backends", "lookup_batch", "update_batch",
    "insert_batch", "remove_batch", "range_scan", "rebuild", "traverse_probe",
    "OpReport", "BuildReport", "lookup_variant", "VARIANTS",
    "FsckReport", "check_tree", "FaultInjected", "ShardDropped",
    "FaultSpec", "FaultPlan", "RetryPolicy", "TreeVersionManager",
    "PublishReport",
]
