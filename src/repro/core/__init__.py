"""FB+-tree core: the paper's data structure + batched latch-free ops in JAX."""
from .fbtree import FBTree, TreeConfig, bulk_build
from .keys import KeySet, make_keyset, encode_uint64, encode_int64
from .branch import traverse, branch_level, BranchStats
from .leaf import probe
from .batch_ops import (lookup_batch, update_batch, insert_batch, remove_batch,
                        range_scan, OpReport)
from .baseline import lookup_variant, VARIANTS

__all__ = [
    "FBTree", "TreeConfig", "bulk_build", "KeySet", "make_keyset",
    "encode_uint64", "encode_int64", "traverse", "branch_level", "BranchStats",
    "probe", "lookup_batch", "update_batch", "insert_batch", "remove_batch",
    "range_scan", "OpReport", "lookup_variant", "VARIANTS",
]
