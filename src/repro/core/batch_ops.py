"""Bulk-synchronous batched tree operations with latch-free-update semantics.

The paper's latch-free update (§4.4) shrinks the critical section to a single
CAS install; reads and unrelated updates never block. The TPU-native analogue
(DESIGN.md §2): operations are batched, everything except the final install
(traversal, hashtag probing, validation) runs data-parallel, and the only
serialized step is one scatter whose conflicts are resolved by a
*deterministic reduction* — last-writer-wins by per-op sequence number,
mirroring "updates only contend on the same key-value pairs".

Inserts use the link-technique-equivalent bulk split: overflowing leaves are
repacked into sorted chunks; the first chunk stays at the original node id so
parent child pointers stay valid (exactly the paper's "transfer the greater
half into the new node n'"), new anchors propagate bottom-up, and versions are
bumped for insert/remove but *not* for update (§4.2, Fig. 7).

Every tree array carries one trailing scratch row (index ``shape[0]-1``) that
masked scatters dump into; watermarks never allocate it.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro import obs

from .branch import BranchStats
from .fbtree import (BIG, EMPTY, FBTree, Level, TreeArrays,
                     _device_build_from_sorted, chunk_of_pos, chunk_start,
                     recompute_inner_meta, stack_levels)
from .keys import (compare_padded, fnv1a_tags, lex_sort_indices_j,
                   pack_words_j)
from .leaf import probe
from .traverse import TraversalEngine, resolve_engine

__all__ = [
    "OpReport", "BuildReport", "lookup_batch", "update_batch", "insert_batch",
    "remove_batch", "range_scan", "rebuild", "dedupe_last_wins",
    "traverse_path", "traverse_probe", "gather_live_sorted",
]


class OpReport(NamedTuple):
    found: jnp.ndarray          # bool [B]
    conflicts: jnp.ndarray      # int32 scalar — ops superseded inside batch
    splits: jnp.ndarray         # int32 scalar — leaves split
    error: jnp.ndarray          # bool scalar — capacity violated
    feat_rounds: jnp.ndarray    # int32 [B]
    suffix_bs: jnp.ndarray      # int32 [B]
    key_compares: jnp.ndarray   # int32 [B]
    lines_touched: jnp.ndarray  # int32 [B]
    tag_candidates: jnp.ndarray  # int32 [B]


def _report(found, bstats: BranchStats, lstats=None, conflicts=0, splits=0,
            error=False):
    """``bstats``/``lstats`` may be ``None`` (stats-free engines,
    DESIGN.md §3): counters come back all-zero, ``found`` stays exact."""
    b = found.shape[0]
    z = jnp.zeros((b,), jnp.int32)
    if bstats is None:
        bstats = BranchStats.zeros(b)
    return OpReport(
        found=found,
        conflicts=jnp.asarray(conflicts, jnp.int32),
        splits=jnp.asarray(splits, jnp.int32),
        error=jnp.asarray(error, bool),
        feat_rounds=bstats.feat_rounds,
        suffix_bs=bstats.suffix_bs,
        key_compares=bstats.key_compares,
        lines_touched=bstats.lines_touched + (lstats.lines_touched if lstats else z),
        tag_candidates=(lstats.tag_candidates if lstats else z),
    )


@functools.partial(jax.jit, static_argnames=("sibling_check", "engine"))
def traverse_path(tree: FBTree, qb, ql, sibling_check: bool = True,
                  engine: Optional[TraversalEngine] = None):
    """Root-to-leaf traversal recording the node id at every level.

    Delegates to the traversal engine (backend + layout selection); kept as
    the stable call-site API for ops and benchmarks. Jitted (engine is
    static), so benchmarks can time the bare descent without probe work.
    """
    return resolve_engine(engine).traverse(tree, qb, ql,
                                           sibling_check=sibling_check)


def _traverse_probe(tree: FBTree, qb, ql, engine, sibling_check=True):
    """The shared descend+probe pipeline every point op runs: one engine
    descent, one hashtag leaf probe. Returns
    (leaf_ids, path, found, slot, val, branch_stats, leaf_stats).

    Descent backends exposing a fused traverse+probe entry (DESIGN.md §3,
    e.g. ``"fused"``) collapse the whole pipeline into one kernel launch;
    level backends run the engine descent followed by the probe. Stats may
    be ``None`` under a stats-free engine — ``_report`` zero-fills.
    """
    eng = resolve_engine(engine)
    fused = eng.probe_path()
    if fused is not None:
        return fused(tree, qb, ql, sibling_check=sibling_check,
                     collect_stats=eng.collect_stats)
    leaf_ids, path, bstats = eng.traverse(
        tree, qb, ql, sibling_check=sibling_check)
    found, slot, val, lstats = probe(tree, leaf_ids, qb, ql,
                                     collect_stats=eng.collect_stats)
    return leaf_ids, path, found, slot, val, bstats, lstats


@functools.partial(jax.jit, static_argnames=("engine", "sibling_check"))
def traverse_probe(tree: FBTree, qb, ql,
                   engine: Optional[TraversalEngine] = None,
                   sibling_check: bool = True):
    """Jitted public traverse+probe (see ``_traverse_probe``)."""
    return _traverse_probe(tree, qb, ql, engine, sibling_check)


def dedupe_last_wins(qb, ql, seq) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Deterministic in-batch conflict resolution: highest seq per key wins."""
    words = pack_words_j(qb)                      # [B, W]
    B, W = words.shape
    perm = jnp.argsort(seq, stable=True)

    def resort(col, perm):
        return jnp.take(perm, jnp.argsort(jnp.take(col, perm), stable=True))

    perm = resort(ql, perm)                       # length = least significant
    for col in range(W - 1, -1, -1):
        perm = resort(words[:, col], perm)
    sb = jnp.take(words, perm, axis=0)
    sl = jnp.take(ql, perm)
    same_next = jnp.concatenate([
        (sb[1:] == sb[:-1]).all(-1) & (sl[1:] == sl[:-1]),
        jnp.zeros((1,), bool)])
    keep_sorted = ~same_next                      # last of each equal-run wins
    winners = jnp.zeros((B,), bool).at[perm].set(keep_sorted)
    return winners, (B - keep_sorted.sum()).astype(jnp.int32)


def rowwise_lex_argsort(kb, kl, valid):
    """argsort rows of kb [R,T,L] by (valid desc, key bytes asc, len asc)."""
    R, T, L = kb.shape
    words = pack_words_j(kb)                      # [R, T, W]
    W = words.shape[-1]
    perm = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32), (R, T))

    def resort(col_vals, perm):
        v = jnp.take_along_axis(col_vals, perm, axis=-1)
        idx = jnp.argsort(v, axis=-1, stable=True)
        return jnp.take_along_axis(perm, idx, axis=-1)

    perm = resort(kl, perm)
    for col in range(W - 1, -1, -1):
        perm = resort(words[..., col], perm)
    perm = resort((~valid).astype(jnp.int32), perm)  # invalid → end
    return perm


def _seg_head_rank(sorted_ids: jnp.ndarray):
    """(is_head, rank-within-run) for a sorted id array."""
    n = sorted_ids.shape[0]
    idx = jnp.arange(n, dtype=jnp.int32)
    is_head = jnp.concatenate([jnp.ones((1,), bool),
                               sorted_ids[1:] != sorted_ids[:-1]])
    head_pos = jnp.where(is_head, idx, 0)
    head_pos = jax.lax.associative_scan(jnp.maximum, head_pos)
    return is_head, idx - head_pos


# --------------------------------------------------------------------------
# lookup / update / remove
# --------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("sibling_check", "engine"))
def _lookup_batch_jit(tree: FBTree, qb, ql, sibling_check: bool = True,
                      engine: Optional[TraversalEngine] = None):
    _, _, found, slot, val, bstats, lstats = _traverse_probe(
        tree, qb, ql, engine, sibling_check)
    return val, _report(found, bstats, lstats)


def lookup_batch(tree: FBTree, qb, ql, sibling_check: bool = True,
                 engine: Optional[TraversalEngine] = None):
    """Batched point lookup. Returns (vals [B], report).

    Telemetry (DESIGN.md §9): with ``repro.obs`` enabled, the launch runs
    under a host span (latency histogram ``span.op.lookup``) and the
    report's device counters drain into the registry — one host sync per
    batch. With it off (the default) this is the bare jitted call; the
    traced program is identical either way.
    """
    if not obs.enabled():
        return _lookup_batch_jit(tree, qb, ql, sibling_check, engine)
    with obs.span("op.lookup"):
        val, rep = _lookup_batch_jit(tree, qb, ql, sibling_check, engine)
        obs.drain_op_report("lookup", rep)
    return val, rep


@functools.partial(jax.jit, static_argnames=("engine",))
def _update_batch_jit(tree: FBTree, qb, ql, vals,
                      engine: Optional[TraversalEngine] = None, mask=None):
    """Blind value update for existing keys (latch-free CAS analogue).

    Does NOT bump leaf versions (§4.2 — readers never restart on updates).
    ``mask`` (bool [B], optional) is the routed-op hook (DESIGN.md §7):
    lanes with ``mask=False`` never write — the shard router passes
    ``owner == s`` so only a key's owning shard commits it. ``found`` is
    reported for every lane regardless of mask.
    """
    B = qb.shape[0]
    a = tree.arrays
    dump = a.leaf_occ.shape[0] - 1
    winners, conflicts = dedupe_last_wins(qb, ql, jnp.arange(B, dtype=jnp.int32))
    if mask is not None:
        winners = winners & mask
    leaf_ids, _, found, slot, _, bstats, lstats = _traverse_probe(
        tree, qb, ql, engine)
    do = winners & found
    li = jnp.where(do, leaf_ids, dump)
    lv = a.leaf_val.at[li, slot].set(
        jnp.where(do, vals.astype(a.leaf_val.dtype), a.leaf_val[li, slot]))
    return tree.replace(leaf_val=lv), _report(found, bstats, lstats,
                                              conflicts=conflicts)


def update_batch(tree: FBTree, qb, ql, vals,
                 engine: Optional[TraversalEngine] = None, mask=None):
    """Instrumented wrapper over the jitted blind update (see the jit
    body's docstring; same obs contract as :func:`lookup_batch`)."""
    if not obs.enabled():
        return _update_batch_jit(tree, qb, ql, vals, engine, mask)
    with obs.span("op.update"):
        tree2, rep = _update_batch_jit(tree, qb, ql, vals, engine, mask)
        obs.drain_op_report("update", rep)
    return tree2, rep


@functools.partial(jax.jit, static_argnames=("engine",))
def _remove_batch_jit(tree: FBTree, qb, ql,
                      engine: Optional[TraversalEngine] = None, mask=None):
    """Tombstone removal (slot cleared, version bumped). ``mask`` gates
    writes exactly as in :func:`update_batch` (routed-op hook)."""
    B = qb.shape[0]
    a = tree.arrays
    dump = a.leaf_occ.shape[0] - 1
    winners, conflicts = dedupe_last_wins(qb, ql, jnp.arange(B, dtype=jnp.int32))
    if mask is not None:
        winners = winners & mask
    leaf_ids, _, found, slot, _, bstats, lstats = _traverse_probe(
        tree, qb, ql, engine)
    do = winners & found
    li = jnp.where(do, leaf_ids, dump)
    occ = a.leaf_occ.at[li, slot].set(jnp.where(do, False, a.leaf_occ[li, slot]))
    kid = a.leaf_keyid.at[li, slot].set(
        jnp.where(do, EMPTY, a.leaf_keyid[li, slot]))
    ver = a.leaf_version.at[li].add(do.astype(jnp.int32))
    return (tree.replace(leaf_occ=occ, leaf_keyid=kid, leaf_version=ver),
            _report(found, bstats, lstats, conflicts=conflicts))


def remove_batch(tree: FBTree, qb, ql,
                 engine: Optional[TraversalEngine] = None, mask=None):
    """Instrumented wrapper over the jitted tombstone removal (same obs
    contract as :func:`lookup_batch`)."""
    if not obs.enabled():
        return _remove_batch_jit(tree, qb, ql, engine, mask)
    with obs.span("op.remove"):
        tree2, rep = _remove_batch_jit(tree, qb, ql, engine, mask)
        obs.drain_op_report("remove", rep)
    return tree2, rep


# --------------------------------------------------------------------------
# insert (upsert)
# --------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("engine",))
def _prepare_insert(tree: FBTree, qb, ql, vals,
                    engine: Optional[TraversalEngine] = None, mask=None):
    """Dedupe, update existing keys in place, append new key bytes to pool.

    ``mask`` (routed-op hook): masked-out lanes lose the dedupe outright,
    so they neither update in place nor append to the pool — the shard
    layer inserts each key only into its owning shard."""
    B = qb.shape[0]
    a = tree.arrays
    ldump = a.leaf_occ.shape[0] - 1
    kdump = a.key_bytes.shape[0] - 1
    winners, conflicts = dedupe_last_wins(qb, ql, jnp.arange(B, dtype=jnp.int32))
    if mask is not None:
        winners = winners & mask
    leaf_ids, _, found, slot, _, bstats, lstats = _traverse_probe(
        tree, qb, ql, engine)

    upd = winners & found
    li = jnp.where(upd, leaf_ids, ldump)
    lv = a.leaf_val.at[li, slot].set(
        jnp.where(upd, vals.astype(a.leaf_val.dtype), a.leaf_val[li, slot]))

    is_new = winners & ~found
    offs = jnp.cumsum(is_new.astype(jnp.int32)) - 1
    kid_op = jnp.where(is_new, a.key_count + offs, EMPTY)
    n_new = is_new.sum().astype(jnp.int32)
    err = (a.key_count + n_new) > kdump
    dst = jnp.where(is_new & (kid_op < kdump), kid_op, kdump)
    kb_new = a.key_bytes.at[dst].set(jnp.where(is_new[:, None], qb, a.key_bytes[dst]))
    kl_new = a.key_lens.at[dst].set(jnp.where(is_new, ql, a.key_lens[dst]))
    kt_new = a.key_tags.at[dst].set(
        jnp.where(is_new, fnv1a_tags(qb, ql), a.key_tags[dst]))

    tree2 = tree.replace(leaf_val=lv, key_bytes=kb_new, key_lens=kl_new,
                         key_tags=kt_new, key_count=a.key_count + n_new)
    return tree2, kid_op, is_new, _report(found, bstats, lstats,
                                          conflicts=conflicts, error=err)


def _make_insert_round(cfg, max_ov: int, ins_cap: int,
                       engine: Optional[TraversalEngine] = None):
    """Build the jitted per-round insert function (static shapes)."""
    ns, fs, L = cfg.ns, cfg.fs, cfg.key_width
    lfill = cfg.leaf_fill
    ifill = cfg.inner_fill
    C_MAX = -(-(ns + ins_cap) // lfill) + 1
    # worst-case anchors arriving at one parent: every one of its <= ns ov
    # children contributes C_MAX-1 new chunks (one extra level of slack; the
    # error flag + raise in insert_batch is the backstop for pathologies)
    IN_CAP = min(max_ov, ns) * (C_MAX - 1) + ns

    def _repack_rows(kb_store, kl_store, item_a, item_b, item_valid, row_valid,
                     fill, c_max):
        """Sort row workspaces and chunk them. Returns dict of chunking state."""
        akb = kb_store[jnp.maximum(item_a, 0)]
        akl = jnp.where(item_valid, kl_store[jnp.maximum(item_a, 0)], 0)
        sperm = rowwise_lex_argsort(akb, akl, item_valid)
        g = lambda x: jnp.take_along_axis(x, sperm, axis=-1)
        item_a, item_b, item_valid = g(item_a), g(item_b), g(item_valid)
        T = item_a.shape[1]
        Tcnt = item_valid.sum(-1).astype(jnp.int32)
        n_chunks = jnp.where(row_valid, -(-Tcnt // fill), 0).astype(jnp.int32)
        base = Tcnt // jnp.maximum(n_chunks, 1)
        rem = Tcnt - base * jnp.maximum(n_chunks, 1)
        pos = jnp.arange(T, dtype=jnp.int32)[None, :]
        chunk = chunk_of_pos(pos, base[:, None], rem[:, None])
        chunk = jnp.where(item_valid, jnp.minimum(chunk, c_max - 1), c_max - 1)
        slot_in_chunk = pos - chunk_start(chunk, base[:, None], rem[:, None])
        cidx = jnp.arange(c_max, dtype=jnp.int32)[None, :]
        cstart = chunk_start(cidx, base[:, None], rem[:, None])
        chunk_exists = (cidx < n_chunks[:, None]) & row_valid[:, None]
        csize = (base[:, None] + (cidx < rem[:, None])).astype(jnp.int32)
        cmin = jnp.take_along_axis(item_a, jnp.minimum(cstart, T - 1), axis=-1)
        return dict(a=item_a, b=item_b, valid=item_valid, Tcnt=Tcnt,
                    n_chunks=n_chunks, chunk=chunk, slot=slot_in_chunk,
                    cidx=cidx, chunk_exists=chunk_exists, csize=csize, cmin=cmin)

    def round_fn(tree: FBTree, kid_op, pending, vals):
        a = tree.arrays
        B = kid_op.shape[0]
        LC = a.leaf_occ.shape[0]
        ldump = LC - 1
        qb = a.key_bytes[jnp.maximum(kid_op, 0)]
        ql = jnp.where(pending, a.key_lens[jnp.maximum(kid_op, 0)], 0)
        leaf_ids, path, _ = resolve_engine(engine).traverse(
            tree, qb, ql, sibling_check=False)
        leaf_ids = jnp.where(pending, leaf_ids, ldump)

        perm = jnp.argsort(jnp.where(pending, leaf_ids, BIG), stable=True)
        s_leaf = jnp.take(leaf_ids, perm)
        s_pending = jnp.take(pending, perm)
        s_kid = jnp.take(kid_op, perm)
        s_val = jnp.take(vals, perm)
        is_head, rank = _seg_head_rank(s_leaf)

        cnt_leaf = jnp.zeros((LC,), jnp.int32).at[
            jnp.where(s_pending, s_leaf, ldump)].add(s_pending.astype(jnp.int32))
        occ_cnt = a.leaf_occ.sum(-1).astype(jnp.int32)
        fits_leaf = (occ_cnt + cnt_leaf) <= ns

        # ---------- fit path ----------
        s_fit = s_pending & fits_leaf[s_leaf]
        occ_rows = a.leaf_occ[s_leaf]
        free_order = jnp.argsort(occ_rows.astype(jnp.int32), axis=-1, stable=True)
        slot = jnp.take_along_axis(free_order, jnp.minimum(rank, ns - 1)[:, None],
                                   axis=-1)[:, 0]
        li = jnp.where(s_fit, s_leaf, ldump)
        sel = lambda new, old: jnp.where(s_fit, new, old)
        leaf_keyid = a.leaf_keyid.at[li, slot].set(sel(s_kid, a.leaf_keyid[li, slot]))
        leaf_val = a.leaf_val.at[li, slot].set(
            sel(s_val.astype(a.leaf_val.dtype), a.leaf_val[li, slot]))
        leaf_tags = a.leaf_tags.at[li, slot].set(
            sel(a.key_tags[jnp.maximum(s_kid, 0)], a.leaf_tags[li, slot]))
        leaf_occ = a.leaf_occ.at[li, slot].set(sel(True, a.leaf_occ[li, slot]))
        leaf_version = a.leaf_version.at[li].add(s_fit.astype(jnp.int32))
        leaf_ordered = a.leaf_ordered.at[li].set(
            jnp.where(s_fit, False, a.leaf_ordered[li]))
        done_sorted = s_fit

        # ---------- overflow path ----------
        ov_head = is_head & s_pending & ~fits_leaf[s_leaf]
        ov_head_pos = jnp.argsort(
            jnp.where(ov_head, jnp.arange(B, dtype=jnp.int32), BIG),
            stable=True)[:max_ov]
        ov_valid = jnp.take(ov_head, ov_head_pos)
        ov_leaf = jnp.where(ov_valid, jnp.take(s_leaf, ov_head_pos), EMPTY)
        ov_repop = jnp.where(ov_valid, jnp.take(perm, ov_head_pos), 0)

        ov_rank_of_leaf = jnp.full((LC,), BIG).at[
            jnp.where(ov_valid, ov_leaf, ldump)].set(
            jnp.where(ov_valid, jnp.arange(max_ov, dtype=jnp.int32), BIG))
        op_ovr = ov_rank_of_leaf[s_leaf]
        s_proc = s_pending & ~fits_leaf[s_leaf] & (op_ovr < max_ov) & (rank < ins_cap)
        done_sorted = done_sorted | s_proc

        ovl = jnp.where(ov_valid, ov_leaf, ldump)
        ws_kid = jnp.concatenate(
            [a.leaf_keyid[ovl], jnp.full((max_ov, ins_cap), EMPTY, jnp.int32)], axis=1)
        ws_val = jnp.concatenate(
            [a.leaf_val[ovl], jnp.zeros((max_ov, ins_cap), a.leaf_val.dtype)], axis=1)
        ws_valid = jnp.concatenate(
            [a.leaf_occ[ovl] & ov_valid[:, None],
             jnp.zeros((max_ov, ins_cap), bool)], axis=1)
        ri = jnp.where(s_proc, op_ovr, max_ov - 1)
        ci = jnp.where(s_proc, ns + jnp.minimum(rank, ins_cap - 1), 0)
        selp = lambda new, old: jnp.where(s_proc, new, old)
        ws_kid = ws_kid.at[ri, ci].set(selp(s_kid, ws_kid[ri, ci]))
        ws_val = ws_val.at[ri, ci].set(
            selp(s_val.astype(a.leaf_val.dtype), ws_val[ri, ci]))
        ws_valid = ws_valid.at[ri, ci].set(selp(True, ws_valid[ri, ci]))

        rp = _repack_rows(a.key_bytes, a.key_lens, ws_kid, ws_val, ws_valid,
                          ov_valid, lfill, C_MAX)

        new_per_row = jnp.maximum(rp["n_chunks"] - 1, 0)
        new_base = a.leaf_count + jnp.cumsum(new_per_row) - new_per_row
        err = (a.leaf_count + new_per_row.sum()) > ldump

        dst_leaf = jnp.where(rp["chunk"] == 0, ovl[:, None],
                             new_base[:, None] + rp["chunk"] - 1)
        dst_leaf = jnp.where(rp["valid"] & (rp["chunk"] < rp["n_chunks"][:, None]),
                             dst_leaf, ldump)

        clr = ovl
        leaf_occ = leaf_occ.at[clr].set(
            jnp.where(ov_valid[:, None], False, leaf_occ[clr]))
        leaf_keyid = leaf_keyid.at[clr].set(
            jnp.where(ov_valid[:, None], EMPTY, leaf_keyid[clr]))

        fvalid = rp["valid"].reshape(-1)
        fl = jnp.where(fvalid, dst_leaf.reshape(-1), ldump)
        fsl = jnp.where(fvalid, jnp.clip(rp["slot"], 0, ns - 1).reshape(-1), ns - 1)
        fkid = rp["a"].reshape(-1)
        w = lambda arr, val: arr.at[fl, fsl].set(jnp.where(fvalid, val, arr[fl, fsl]))
        leaf_keyid = w(leaf_keyid, fkid)
        leaf_val = w(leaf_val, rp["b"].reshape(-1))
        leaf_tags = w(leaf_tags, a.key_tags[jnp.maximum(fkid, 0)])
        leaf_occ = w(leaf_occ, jnp.ones_like(fvalid))

        cidx, chunk_exists, cmin = rp["cidx"], rp["chunk_exists"], rp["cmin"]
        chunk_leaf = jnp.where(cidx == 0, ovl[:, None], new_base[:, None] + cidx - 1)
        next_chunk_leaf = jnp.where(cidx + 1 < rp["n_chunks"][:, None],
                                    new_base[:, None] + cidx,
                                    a.leaf_next[ovl][:, None])
        chunk_high = jnp.where(
            cidx + 1 < rp["n_chunks"][:, None],
            jnp.take_along_axis(cmin, jnp.minimum(cidx + 1, C_MAX - 1), axis=-1),
            a.leaf_high[ovl][:, None])
        wmask = chunk_exists.reshape(-1)
        wl = jnp.where(wmask, chunk_leaf.reshape(-1), ldump)
        leaf_next = a.leaf_next.at[wl].set(
            jnp.where(wmask, next_chunk_leaf.reshape(-1), a.leaf_next[wl]))
        leaf_high = a.leaf_high.at[wl].set(
            jnp.where(wmask, chunk_high.reshape(-1), a.leaf_high[wl]))
        leaf_version = leaf_version.at[wl].add(wmask.astype(jnp.int32))
        leaf_ordered = leaf_ordered.at[wl].set(
            jnp.where(wmask, True, leaf_ordered[wl]))
        leaf_count = a.leaf_count + new_per_row.sum().astype(jnp.int32)
        n_splits = ov_valid.sum().astype(jnp.int32)

        arrays = a._replace(
            leaf_keyid=leaf_keyid, leaf_val=leaf_val, leaf_tags=leaf_tags,
            leaf_occ=leaf_occ, leaf_high=leaf_high, leaf_next=leaf_next,
            leaf_version=leaf_version, leaf_ordered=leaf_ordered,
            leaf_count=leaf_count)

        # tuples for the parent level: (parent node, anchor kid, child, rep-op)
        tup_mask = (chunk_exists & (cidx >= 1)).reshape(-1)
        tup_repop = jnp.broadcast_to(ov_repop[:, None], (max_ov, C_MAX)).reshape(-1)
        tup_parent = jnp.where(tup_mask, jnp.take(path[-1], tup_repop), EMPTY)
        tup_anchor = jnp.where(tup_mask, cmin.reshape(-1), EMPTY)
        tup_child = jnp.where(tup_mask, chunk_leaf.reshape(-1), EMPTY)

        new_levels = list(arrays.levels)
        for lvl in range(len(arrays.levels) - 1, -1, -1):
            parent_path = path[lvl - 1] if lvl > 0 else None
            (lvl2, tup_parent, tup_anchor, tup_child, tup_repop, e) = _inner_insert(
                new_levels[lvl], arrays, tup_parent, tup_anchor, tup_child,
                tup_repop, parent_path)
            new_levels[lvl] = lvl2
            err = err | e
        # keep both descent layouts coherent: splits rewrote inner nodes,
        # so re-derive the stacked copy in-graph (pad + stack, shape-static)
        arrays = arrays._replace(levels=tuple(new_levels),
                                 stacked=stack_levels(tuple(new_levels)))

        done_orig = jnp.zeros((B,), bool).at[perm].set(done_sorted)
        new_pending = pending & ~done_orig
        return FBTree(tree.config, arrays), new_pending, n_splits, err

    def _inner_insert(level: Level, arrays: TreeArrays,
                      tup_parent, tup_anchor, tup_child, tup_repop, parent_path):
        """Insert (anchor, child) tuples into one inner level; emit next tuples."""
        NT = tup_parent.shape[0]
        capn = level.knum.shape[0]
        ndump = capn - 1
        kb_store, kl_store = arrays.key_bytes, arrays.key_lens
        is_root = parent_path is None

        tv = tup_parent >= 0
        perm = jnp.argsort(jnp.where(tv, tup_parent, BIG), stable=True)
        sp = jnp.take(tup_parent, perm)
        sa = jnp.take(tup_anchor, perm)
        sc = jnp.take(tup_child, perm)
        sr = jnp.take(tup_repop, perm)
        stv = jnp.take(tv, perm)
        is_head, rank = _seg_head_rank(sp)

        R = max_ov
        head_pos = jnp.argsort(jnp.where(is_head & stv,
                                         jnp.arange(NT, dtype=jnp.int32), BIG),
                               stable=True)[:R]
        row_valid = jnp.take(is_head & stv, head_pos)
        row_node = jnp.where(row_valid, jnp.take(sp, head_pos), EMPTY)
        row_repop = jnp.where(row_valid, jnp.take(sr, head_pos), 0)
        rank_of_node = jnp.full((capn,), BIG).at[
            jnp.where(row_valid, row_node, ndump)].set(
            jnp.where(row_valid, jnp.arange(R, dtype=jnp.int32), BIG))
        op_row = rank_of_node[jnp.maximum(sp, 0)]
        s_ok = stv & (op_row < R) & (rank < IN_CAP)
        err = (stv & ~s_ok).any()

        rn = jnp.where(row_valid, row_node, ndump)
        lane = jnp.arange(ns, dtype=jnp.int32)[None, :]
        ws_anchor = jnp.concatenate(
            [level.anchors[rn], jnp.full((R, IN_CAP), EMPTY, jnp.int32)], axis=1)
        ws_child = jnp.concatenate(
            [level.children[rn], jnp.full((R, IN_CAP), EMPTY, jnp.int32)], axis=1)
        ws_valid = jnp.concatenate(
            [(lane < level.knum[rn][:, None]) & row_valid[:, None],
             jnp.zeros((R, IN_CAP), bool)], axis=1)
        ri = jnp.where(s_ok, op_row, R - 1)
        ci = jnp.where(s_ok, ns + jnp.minimum(rank, IN_CAP - 1), 0)
        selp = lambda new, old: jnp.where(s_ok, new, old)
        ws_anchor = ws_anchor.at[ri, ci].set(selp(sa, ws_anchor[ri, ci]))
        ws_child = ws_child.at[ri, ci].set(selp(sc, ws_child[ri, ci]))
        ws_valid = ws_valid.at[ri, ci].set(selp(True, ws_valid[ri, ci]))

        CI_MAX = -(-(ns + IN_CAP) // ifill) + 1
        rp = _repack_rows(kb_store, kl_store, ws_anchor, ws_child, ws_valid,
                          row_valid, ifill, CI_MAX)
        n_chunks = rp["n_chunks"]
        if is_root:
            err = err | (n_chunks > 1).any() | (rp["Tcnt"] > ns).any()
            n_chunks = jnp.minimum(n_chunks, 1)

        new_per_row = jnp.maximum(n_chunks - 1, 0)
        new_base = level.count + jnp.cumsum(new_per_row) - new_per_row
        err = err | ((level.count + new_per_row.sum()) > ndump)

        dst_node = jnp.where(rp["chunk"] == 0, rn[:, None],
                             new_base[:, None] + rp["chunk"] - 1)
        dst_node = jnp.where(rp["valid"] & (rp["chunk"] < n_chunks[:, None]),
                             dst_node, ndump)

        anchors_new = level.anchors.at[rn].set(
            jnp.where(row_valid[:, None], EMPTY, level.anchors[rn]))
        children_new = level.children.at[rn].set(
            jnp.where(row_valid[:, None], EMPTY, level.children[rn]))
        fvalid = (rp["valid"] & (rp["slot"] < ns) & (rp["slot"] >= 0)
                  & (rp["chunk"] < n_chunks[:, None])).reshape(-1)
        fn = jnp.where(fvalid, dst_node.reshape(-1), ndump)
        fsl = jnp.where(fvalid, jnp.clip(rp["slot"], 0, ns - 1).reshape(-1), ns - 1)
        anchors_new = anchors_new.at[fn, fsl].set(
            jnp.where(fvalid, rp["a"].reshape(-1), anchors_new[fn, fsl]))
        children_new = children_new.at[fn, fsl].set(
            jnp.where(fvalid, rp["b"].reshape(-1), children_new[fn, fsl]))

        cidx = rp["cidx"]
        chunk_exists = (cidx < n_chunks[:, None]) & row_valid[:, None]
        csize = jnp.minimum(rp["csize"], ns)
        cnode = jnp.where(cidx == 0, rn[:, None], new_base[:, None] + cidx - 1)
        wm = chunk_exists.reshape(-1)
        wn = jnp.where(wm, cnode.reshape(-1), ndump)
        knum_new = level.knum.at[wn].set(
            jnp.where(wm, csize.reshape(-1), level.knum[wn]))

        sub_anch = anchors_new[wn]
        sub_knum = knum_new[wn]
        pl, pf, ft = recompute_inner_meta(kb_store, kl_store, sub_anch,
                                           sub_knum, fs)
        plen_new = level.plen.at[wn].set(jnp.where(wm, pl, level.plen[wn]))
        prefix_new = level.prefix.at[wn].set(
            jnp.where(wm[:, None], pf, level.prefix[wn]))
        feats_new = level.features.at[wn].set(
            jnp.where(wm[:, None, None], ft, level.features[wn]))
        count_new = level.count + new_per_row.sum().astype(jnp.int32)

        level2 = Level(knum=knum_new, plen=plen_new, prefix=prefix_new,
                       features=feats_new, children=children_new,
                       anchors=anchors_new, count=count_new)

        nt_mask = (chunk_exists & (cidx >= 1)).reshape(-1)
        nt_repop = jnp.broadcast_to(row_repop[:, None], (R, CI_MAX)).reshape(-1)
        if is_root:
            nt_parent = jnp.full((R * CI_MAX,), EMPTY, jnp.int32)
        else:
            nt_parent = jnp.where(nt_mask, jnp.take(parent_path, nt_repop), EMPTY)
        nt_anchor = jnp.where(nt_mask, rp["cmin"].reshape(-1), EMPTY)
        nt_child = jnp.where(nt_mask, cnode.reshape(-1), EMPTY)
        return level2, nt_parent, nt_anchor, nt_child, nt_repop, err

    return jax.jit(round_fn)


_ROUND_CACHE = {}


def insert_batch(tree: FBTree, qb, ql, vals, max_ov: int = 128,
                 ins_cap: int = None, max_rounds: int = 64,
                 engine: Optional[TraversalEngine] = None, mask=None):
    """Batched upsert. Returns (tree', report, rounds).

    Orchestrates: dedupe/update/append (one jitted call) + split rounds
    (jitted, bounded work per round) until no ops are pending. ``ins_cap``
    bounds keys absorbed per leaf per round (default 4*ns — monotone-append
    workloads funnel a whole batch into the rightmost leaf). ``mask``
    (bool [B], optional) is the routed-op hook: masked-out lanes are
    no-ops — no in-place update, no pool append, never pending.

    Telemetry: same obs contract as :func:`lookup_batch`, plus an
    ``op.rounds`` counter (split rounds taken, labeled ``op=insert``).
    """
    if not obs.enabled():
        return _insert_batch_impl(tree, qb, ql, vals, max_ov, ins_cap,
                                  max_rounds, engine, mask)
    with obs.span("op.insert"):
        tree2, rep, rounds = _insert_batch_impl(
            tree, qb, ql, vals, max_ov, ins_cap, max_rounds, engine, mask)
        obs.drain_op_report("insert", rep)
        obs.counter("op.rounds", op="insert").inc(rounds)
    return tree2, rep, rounds


def _insert_batch_impl(tree: FBTree, qb, ql, vals, max_ov: int = 128,
                       ins_cap: int = None, max_rounds: int = 64,
                       engine: Optional[TraversalEngine] = None, mask=None):
    qb = jnp.asarray(qb)
    ql = jnp.asarray(ql)
    vals = jnp.asarray(vals)
    max_ov = min(max_ov, qb.shape[0])   # can't overflow more leaves than ops
    if ins_cap is None:
        ins_cap = 4 * tree.config.ns
    # normalize so engine=None and an explicit default engine share one
    # round cache entry / jit specialization
    engine = resolve_engine(engine)
    key = (tree.config, max_ov, ins_cap, engine)
    if key not in _ROUND_CACHE:
        _ROUND_CACHE[key] = _make_insert_round(tree.config, max_ov, ins_cap,
                                               engine)
    round_fn = _ROUND_CACHE[key]

    tree, kid_op, pending, rep = _prepare_insert(tree, qb, ql, vals,
                                                 engine=engine, mask=mask)
    if bool(rep.error):
        raise RuntimeError("insert_batch: key pool capacity exceeded")
    total_splits = jnp.int32(0)
    rounds = 0
    while rounds < max_rounds:
        if not bool(pending.any()):
            break
        tree, pending, n_splits, e = round_fn(tree, kid_op, pending, vals)
        if bool(e):
            raise RuntimeError("insert_batch: capacity violated (leaf/node/"
                               "root overflow) — grow TreeConfig caps")
        total_splits = total_splits + n_splits
        rounds += 1
    if bool(pending.any()):
        raise RuntimeError("insert_batch: ops still pending after "
                           f"{max_rounds} rounds (capacity exhausted?)")
    rep = rep._replace(splits=total_splits)
    return tree, rep, rounds


# --------------------------------------------------------------------------
# range scan
# --------------------------------------------------------------------------

def _range_scan_jnp(tree: FBTree, qb, ql, max_items: int,
                    eng: TraversalEngine, force_sort: bool = False):
    """jnp chain-walk reference for the range scan (DESIGN.md §6).

    One engine descent to the start leaf, then an early-exit
    ``lax.while_loop`` over the sibling chain: lanes retire as they reach
    ``max_items`` or chain end, so short chains stop immediately and
    tombstone-drained chains are walked to completion (the old fixed
    ``ceil(max_items / (leaf_fill // 2)) + 1`` hop bound both over-walked
    and under-filled).

    Lazy rearrangement (§4.5): each hop sorts via ``rowwise_lex_argsort``
    only under a ``lax.cond`` that fires when some *active* lane sits on a
    leaf with its ``leaf_ordered`` bit clear — when every visited leaf is
    ordered, emission is a plain occupancy cumsum in slot order (ordered
    leaves store keys ascending) and, past hop 0, no key bytes are gathered
    at all. Hop 0 is peeled: it is the only hop that needs key bytes
    unconditionally (the start-key compare), and the only hop that filters
    ``key >= query``; hop ≥ 1 leaves emit every occupied slot (the chain
    ascends).

    ``rearranged`` counts the dirty leaves each lane actually visited (the
    leaves a pointer-stable implementation would rearrange); with the
    engine's static ``collect_stats`` off the counter is never traced and
    comes back all-zero. ``force_sort=True`` (static) disables the ordered
    fast path — the always-sort baseline ``benchmarks/scan.py`` A/Bs
    against; outputs are bit-identical either way.
    """
    a = tree.arrays
    ns = tree.config.ns
    B = qb.shape[0]
    dump = a.leaf_occ.shape[0] - 1
    cs = eng.collect_stats
    leaf_ids, _, _ = eng.traverse(tree, qb, ql)
    bidx = jnp.broadcast_to(jnp.arange(B)[:, None], (B, ns))

    # one scratch column at index max_items for masked scatter dumps
    out_kid = jnp.full((B, max_items + 1), EMPTY, jnp.int32)
    out_val = jnp.zeros((B, max_items + 1), a.leaf_val.dtype)
    emitted = jnp.zeros((B,), jnp.int32)

    def emit_to(out_kid, out_val, emitted, kid, val, emit):
        rank = jnp.cumsum(emit.astype(jnp.int32), axis=-1) - 1
        dstpos = emitted[:, None] + rank
        ok = emit & (dstpos < max_items) & (dstpos >= 0)
        dp = jnp.where(ok, dstpos, max_items)     # dump to scratch column
        out_kid = out_kid.at[bidx, dp].set(
            jnp.where(ok, kid, out_kid[bidx, dp]))
        out_val = out_val.at[bidx, dp].set(
            jnp.where(ok, val, out_val[bidx, dp]))
        emitted = jnp.minimum(emitted + emit.sum(-1), max_items)
        return out_kid, out_val, emitted

    # ---- hop 0 (peeled): start-key compare — key bytes gathered here and,
    # on later hops, only inside the dirty-leaf sort branch
    cur = leaf_ids
    kid = a.leaf_keyid[cur]                       # [B, ns]
    val = a.leaf_val[cur]
    occ = a.leaf_occ[cur]
    kb = a.key_bytes[jnp.maximum(kid, 0)]         # [B, ns, L]
    kl = jnp.where(occ, a.key_lens[jnp.maximum(kid, 0)], 0)
    dirty = ~a.leaf_ordered[cur]

    def _as_is(ops):
        return ops

    def _sorted0(ops):
        kid, val, occ, kb, kl = ops
        perm = rowwise_lex_argsort(kb, kl, occ)
        g = lambda x: jnp.take_along_axis(x, perm, axis=-1)
        return (g(kid), g(val), g(occ),
                jnp.take_along_axis(kb, perm[:, :, None], axis=1), g(kl))

    pred = jnp.zeros((), bool) if force_sort else ~dirty.any()
    kid, val, occ, kb, kl = jax.lax.cond(pred, _as_is, _sorted0,
                                         (kid, val, occ, kb, kl))
    emit = occ & (compare_padded(kb, kl, qb[:, None, :], ql[:, None]) >= 0)
    out_kid, out_val, emitted = emit_to(out_kid, out_val, emitted,
                                        kid, val, emit)
    nxt = a.leaf_next[cur]
    cur = jnp.where((nxt >= 0) & (emitted < max_items), nxt, dump)

    # ---- hops 1+: early-exit chain walk (every key of an active leaf
    # emits — the ascending chain guarantees key >= query past hop 0)
    def w_cond(c):
        return (c[0] != dump).any()

    def w_body(c):
        if cs:
            cur, emitted, out_kid, out_val, rearr = c
        else:
            cur, emitted, out_kid, out_val = c
        active = cur != dump
        kid = a.leaf_keyid[cur]
        val = a.leaf_val[cur]
        occ = a.leaf_occ[cur] & active[:, None]
        dirty = active & ~a.leaf_ordered[cur]

        def _sortedh(ops):
            kid, val, occ = ops
            kb = a.key_bytes[jnp.maximum(kid, 0)]
            kl = jnp.where(occ, a.key_lens[jnp.maximum(kid, 0)], 0)
            perm = rowwise_lex_argsort(kb, kl, occ)
            g = lambda x: jnp.take_along_axis(x, perm, axis=-1)
            return g(kid), g(val), g(occ)

        pred = jnp.zeros((), bool) if force_sort else ~dirty.any()
        kid, val, occ = jax.lax.cond(pred, _as_is, _sortedh, (kid, val, occ))
        out_kid2, out_val2, emitted2 = emit_to(out_kid, out_val, emitted,
                                               kid, val, occ)
        nxt = a.leaf_next[cur]
        cur = jnp.where(active & (nxt >= 0) & (emitted2 < max_items),
                        nxt, dump)
        if cs:
            return cur, emitted2, out_kid2, out_val2, \
                rearr + dirty.astype(jnp.int32)
        return cur, emitted2, out_kid2, out_val2

    carry = (cur, emitted, out_kid, out_val)
    if cs:
        carry = carry + (dirty.astype(jnp.int32),)
    final = jax.lax.while_loop(w_cond, w_body, carry)
    _, emitted, out_kid, out_val = final[:4]
    rearranged = final[4] if cs else jnp.zeros((B,), jnp.int32)
    return out_kid[:, :max_items], out_val[:, :max_items], emitted, rearranged


@functools.partial(jax.jit, static_argnames=("max_items", "engine"))
def _range_scan_jit(tree: FBTree, qb, ql, max_items: int = 64,
                    engine: Optional[TraversalEngine] = None):
    eng = resolve_engine(engine)
    fused = eng.scan_path()
    if fused is not None:
        return fused(tree, qb, ql, max_items=max_items,
                     collect_stats=eng.collect_stats)
    return _range_scan_jnp(tree, qb, ql, max_items, eng)


def range_scan(tree: FBTree, qb, ql, max_items: int = 64,
               engine: Optional[TraversalEngine] = None):
    """Batched range scan: for each start key return up to ``max_items``
    ``(key_id, value)`` pairs in ascending key order, starting at the first
    key >= the query (lazy rearrangement: unsorted leaves are sorted on the
    fly, modeling §4.5; ordered leaves skip the sort entirely).

    Dispatches through the engine's scan backend (DESIGN.md §6): a backend
    with a registered whole-scan kernel (``"fused"`` →
    ``kernels/fused_scan``) collapses descent + sibling hop + chain walk
    into one launch; every other backend runs the jnp chain-walk reference
    (:func:`_range_scan_jnp`), descending through the engine as usual.
    Returns ``(out_kid [B, max_items], out_val [B, max_items], emitted [B],
    rearranged [B])``; ``rearranged`` (dirty leaves visited) is all-zero
    under a stats-free engine.

    Telemetry: same obs contract as :func:`lookup_batch` — the span
    histogram is ``span.op.scan``, and ``op.emitted``/``op.rearranged``
    counters drain from the scan outputs (one host sync).
    """
    if max_items < 1:
        raise ValueError(
            f"range_scan: max_items must be >= 1, got {max_items} — each "
            f"lane emits up to max_items (key, value) pairs")
    if not obs.enabled():
        return _range_scan_jit(tree, qb, ql, max_items, engine)
    with obs.span("op.scan"):
        out_kid, out_val, emitted, rearranged = _range_scan_jit(
            tree, qb, ql, max_items, engine)
        em, re = jax.device_get((emitted, rearranged))
        obs.counter("op.calls", op="scan").inc()
        obs.counter("op.lanes", op="scan").inc(int(em.size))
        obs.counter("op.emitted", op="scan").inc(int(em.sum()))
        obs.counter("op.rearranged", op="scan").inc(int(re.sum()))
    return out_kid, out_val, emitted, rearranged


# --------------------------------------------------------------------------
# rebuild — device-side bulk re-construction (DESIGN.md §5)
# --------------------------------------------------------------------------

class BuildReport(NamedTuple):
    """Outcome of a device-side (re)build."""
    n_live: jnp.ndarray     # int32 — keys carried into the new tree
    n_leaves: jnp.ndarray   # int32 — leaves the fresh build allocated
    reclaimed: jnp.ndarray  # int32 — key-pool rows freed (tombstones, dupes)
    error: jnp.ndarray      # bool — capacity exceeded; discard the result


def gather_live_sorted(tree: FBTree):
    """Gather a tree's live key set into a sorted, compacted, pool-shaped
    snapshot: ``(kb, kl, ktags, vals, n_live)`` with rows ``[0, n_live)``
    holding the live keys ascending and zeros everywhere else — exactly the
    input contract of ``fbtree._device_build_from_sorted``.

    Pure jnp (composes under jit): :func:`rebuild` feeds it straight back
    into the device build, and the shard layer (DESIGN.md §7) concatenates
    the per-shard snapshots — already globally sorted, since shards are
    range-partitioned — to re-partition on ``repro.shard.rebalance``.
    """
    a, cfg = tree.arrays, tree.config
    KC, L = cfg.key_cap, cfg.key_width
    occ = a.leaf_occ.reshape(-1)                  # [(leaf_cap+1) * ns]
    kid = jnp.where(occ, a.leaf_keyid.reshape(-1), EMPTY)
    kid_safe = jnp.maximum(kid, 0)
    lens = jnp.where(occ, a.key_lens[kid_safe], 0)
    order = lex_sort_indices_j(a.key_bytes[kid_safe], lens,
                               invalid=~occ)      # live slots first, sorted
    n_live = occ.sum().astype(jnp.int32)
    skid = jnp.maximum(jnp.take(kid, order), 0)
    r = jnp.arange(order.shape[0], dtype=jnp.int32)
    valid = r < n_live                            # n_live <= KC always
    dst = jnp.where(valid, jnp.minimum(r, KC), KC)  # scratch row = KC
    # invalid lanes all scatter 0/EMPTY-free zeros into the scratch row, so
    # the duplicate writes are deterministic and the pool tail stays zero
    kb = jnp.zeros((KC + 1, L), jnp.uint8).at[dst].set(
        jnp.where(valid[:, None], a.key_bytes[skid], 0))
    kl = jnp.zeros((KC + 1,), jnp.int32).at[dst].set(
        jnp.where(valid, a.key_lens[skid], 0))
    ktags = jnp.zeros((KC + 1,), jnp.uint8).at[dst].set(
        jnp.where(valid, a.key_tags[skid], 0))
    vv = jnp.zeros((KC + 1,), a.leaf_val.dtype).at[dst].set(
        jnp.where(valid, jnp.take(a.leaf_val.reshape(-1), order), 0))
    return kb, kl, ktags, vv, n_live


@jax.jit
def _rebuild_jit(tree: FBTree) -> Tuple[FBTree, BuildReport]:
    """Compact a split-fragmented tree by re-running the device bulk build.

    Gathers the live (key id, value) pairs from the leaves
    (:func:`gather_live_sorted`: packed-word lexsort, invalid slots last,
    pool re-packed front-to-back) and reconstructs every level — tuple and
    stacked layouts alike — through ``fbtree._device_build_from_sorted``.
    Entirely jnp, so it composes under jit with the other batch ops.

    Semantics w.r.t. the §2 protocol (DESIGN.md §5): a rebuild is a
    bulk-synchronous barrier. Tombstoned keys are dropped and the pool is
    compacted, so *key ids are not stable across a rebuild*; leaf versions
    reset to zero and sibling links are relinked left-to-right. Results
    cached from before the barrier (leaf ids, key ids, versions) must be
    re-resolved by a fresh traversal. The output tree is exactly what
    ``bulk_build`` (host or device) would produce from the live key set.
    """
    a, cfg = tree.arrays, tree.config
    kb, kl, ktags, vv, n_live = gather_live_sorted(tree)
    arrays, err = _device_build_from_sorted(cfg, kb, kl, ktags, vv, n_live)
    rep = BuildReport(n_live=n_live, n_leaves=arrays.leaf_count,
                      reclaimed=(a.key_count - n_live).astype(jnp.int32),
                      error=err)
    return FBTree(cfg, arrays), rep


def rebuild(tree: FBTree) -> Tuple[FBTree, BuildReport]:
    """Instrumented wrapper over the jitted rebuild barrier (same obs
    contract as :func:`lookup_batch`; span ``span.op.rebuild``, counters
    ``build.n_live``/``build.reclaimed`` labeled ``op=rebuild``)."""
    if not obs.enabled():
        return _rebuild_jit(tree)
    with obs.span("op.rebuild"):
        tree2, rep = _rebuild_jit(tree)
        host = jax.device_get(rep)
        obs.counter("op.calls", op="rebuild").inc()
        obs.counter("build.n_live", op="rebuild").inc(int(host.n_live))
        obs.counter("build.reclaimed",
                    op="rebuild").inc(int(host.reclaimed))
    return tree2, rep
