"""Versioned tree lifecycle: double-buffered atomic publish (DESIGN.md §8).

``TreeVersionManager`` owns the serving tree (FBTree or ShardedTree) and
splits mutations into two classes, mirroring the paper's §2 protocol
promoted from ``core.protocol``'s simulator to the real arrays:

* :meth:`commit`  — in-place batch-op results (insert/update/remove).
  These are already latch-free-safe under the version/link protocol; they
  replace the current object *within* the same published version.
* :meth:`publish` — bulk barriers (``rebuild``, ``rebalance``,
  ``PrefixCache.compact``, ``sharded_build``). The new version is built
  **off to the side**, structurally fsck'd (``core.fsck``), and swapped in
  only on success. Any failure — an exception mid-build, a capacity
  error, an fsck violation on the staged arrays — leaves the previous
  version serving, bit-identical (the staged object is simply dropped).

The manager holds the previous version alongside the current one
(double-buffering): degraded readers and regression tests can address the
last-barrier snapshot explicitly, and the swap itself is a single host
reference assignment — atomic with respect to anything reading
``manager.current``.

Fault sites (``core.faults.FaultPlan.fire``): ``lifecycle.begin``,
``lifecycle.rebuild.gather``, ``lifecycle.rebuild.build``,
``lifecycle.rebalance.barrier``, ``lifecycle.staged`` (corruption),
``lifecycle.fsck``, ``lifecycle.swap``.
"""
from __future__ import annotations

import time
from typing import Any, Callable, List, NamedTuple, Optional, Tuple

import jax.numpy as jnp

from repro import obs

from . import batch_ops as B
from . import fsck
from .faults import FaultInjected, FaultPlan
from .fbtree import FBTree, _device_build_jit

__all__ = ["TreeVersion", "PublishReport", "TreeVersionManager"]


class TreeVersion(NamedTuple):
    obj: Any          # FBTree | ShardedTree
    version: int      # bumps on every successful publish, never on commit
    label: str        # what published it ("initial", "rebuild", ...)


class PublishReport(NamedTuple):
    """Outcome of one publish attempt. ``ok=False`` means the old version
    is still serving; ``reason`` says why (``fault:<site>``,
    ``fsck:<first violation>``, ``build-error``, ``error:<exc>``)."""
    ok: bool
    version: int                 # serving version AFTER the attempt
    label: str
    reason: str
    violations: Tuple[str, ...]
    aux: Any                     # builder's report (BuildReport/...) | None


class TreeVersionManager:
    """Double-buffered tree versions with abortable, fsck-gated publish."""

    def __init__(self, obj, faults: Optional[FaultPlan] = None,
                 verify: bool = True):
        self._current = TreeVersion(obj, 0, "initial")
        self._previous: Optional[TreeVersion] = None
        self.faults = faults
        self.verify = verify
        self.history: List[Tuple[int, str, bool, str]] = [
            (0, "initial", True, "")]

    # ------------------------------------------------------------- reads
    @property
    def current(self):
        """The serving tree. Readers grab this once per batch; the swap in
        :meth:`publish` is a single assignment, so a reader never sees a
        half-built version."""
        return self._current.obj

    @property
    def previous(self):
        """Last-barrier snapshot (None before the first publish)."""
        return self._previous.obj if self._previous is not None else None

    @property
    def version(self) -> int:
        return self._current.version

    @property
    def label(self) -> str:
        return self._current.label

    # ------------------------------------------------------------ writes
    def commit(self, obj) -> None:
        """Adopt an in-place batch-op result under the current version.

        No fsck, no version bump: in-place ops are covered by the leaf
        version/link protocol (readers validate per-leaf), and gating the
        hot path here would serialize serving on a host-side check.
        """
        self._current = self._current._replace(obj=obj)

    def _fire(self, site: str, **ctx):
        if self.faults is not None:
            self.faults.fire(site, **ctx)

    def publish(self, build_fn: Callable[[], Any],
                label: str = "publish") -> PublishReport:
        """Run ``build_fn`` off to the side and swap its result in iff it
        is structurally sound.

        ``build_fn`` returns the staged object, or ``(staged, aux)`` where
        ``aux`` is a builder report (``aux.error`` truthy vetoes the swap
        — e.g. ``BuildReport.error`` flagging a capacity overflow whose
        arrays are shape-valid garbage). Exceptions (including injected
        faults) abort the publish; the current version is untouched on
        every failure path, because it is only reassigned on the last
        line.
        """
        t0 = time.perf_counter()

        def fail(reason: str, violations=(), aux=None) -> PublishReport:
            self.history.append((self.version, label, False, reason))
            obs.event("publish", label=label, version=self.version,
                      ok=False, reason=reason,
                      duration_s=time.perf_counter() - t0)
            return PublishReport(False, self.version, label, reason,
                                 tuple(violations), aux)

        aux = None
        with obs.span("lifecycle.publish", label=label):
            try:
                self._fire("lifecycle.begin", label=label)
                staged = build_fn()
                if isinstance(staged, tuple):
                    staged, aux = staged[0], (staged[1] if len(staged) == 2
                                              else staged[1:])
                if aux is not None and bool(getattr(aux, "error", False)):
                    return fail("build-error", aux=aux)
                if self.faults is not None:
                    staged, _ = self.faults.corrupt_staged(
                        "lifecycle.staged", staged)
                if self.verify:
                    self._fire("lifecycle.fsck", label=label)
                    rep = fsck.check(staged)
                    if not rep.ok:
                        obs.event("fsck", label=label,
                                  violations=list(rep.violations))
                        return fail("fsck:" + rep.violations[0],
                                    violations=rep.violations, aux=aux)
                self._fire("lifecycle.swap", label=label)
            except FaultInjected as e:
                return fail(f"fault:{e.site}", aux=aux)
            except Exception as e:  # a real build bug must not kill serving
                return fail(f"error:{type(e).__name__}: {e}", aux=aux)
            self._previous = self._current
            self._current = TreeVersion(staged, self.version + 1, label)
            self.history.append((self.version, label, True, ""))
            obs.event("publish", label=label, version=self.version, ok=True,
                      reason="", duration_s=time.perf_counter() - t0)
            return PublishReport(True, self.version, label, "", (), aux)

    # --------------------------------------------- barrier conveniences
    def rebuild(self, label: str = "rebuild") -> PublishReport:
        """``batch_ops.rebuild`` as an abortable publish, staged in two
        observable steps (gather, then device build) so a fault can land
        between them. Runs the same jitted primitives as the fused
        ``rebuild`` — the published arrays are bit-identical to it."""
        tree = self.current
        if not isinstance(tree, FBTree):
            raise TypeError("rebuild() needs an FBTree; use rebalance() "
                            "for a ShardedTree")

        def build():
            self._fire("lifecycle.rebuild.gather", label=label)
            kb, kl, ktags, vv, n_live = B.gather_live_sorted(tree)
            self._fire("lifecycle.rebuild.build", label=label)
            arrays, err = _device_build_jit(cfg=tree.config, kb=kb, kl=kl,
                                            ktags=ktags, vals=vv, n=n_live)
            rep = B.BuildReport(
                n_live=n_live, n_leaves=arrays.leaf_count,
                reclaimed=(tree.arrays.key_count - n_live
                           ).astype(jnp.int32),
                error=err)
            return FBTree(tree.config, arrays), rep

        return self.publish(build, label=label)

    def rebalance(self, device: bool = True,
                  label: str = "rebalance") -> PublishReport:
        """``repro.shard.rebalance`` as an abortable publish. Doubles as
        the recovery path for dropped shards: the rebuilt ShardedTree
        starts with fresh (all-healthy) health state and fresh barrier
        snapshots, re-admitting any shard that was marked down."""
        st = self.current
        if isinstance(st, FBTree):
            return self.rebuild(label=label)
        from repro.shard import ops as shard_ops  # lazy: core<->shard

        def build():
            self._fire("lifecycle.rebalance.barrier", label=label)
            return shard_ops.rebalance(st, device=device,
                                       faults=self.faults)

        return self.publish(build, label=label)
