"""Feature-comparison branch (paper §3.2/3.4, Fig. 6 lines 1-28), batched.

Given a batch of queries positioned at nodes of one inner level, resolve each
query's child index using:

  1. common-prefix compare (3-way);
  2. progressive byte-wise parallel feature comparison: per feature row
     ``fid`` an equality mask over all ``ns`` anchors is AND-ed into a running
     run mask; the first row with an empty intersection resolves the branch via
     a less-than mask (``compare_less`` + ``index_least1``/``countl_zero``
     become vectorized mask reductions — no scalar 64-bit packing, which suits
     the TPU VPU better than AVX mask registers);
  3. fallback binary search over anchor *suffixes* when the run survives all
     ``fs`` rows (paper line 23: prefix+feature bytes are skipped).

Everything is pure jnp so the same code is the oracle for the Pallas kernel.
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax.numpy as jnp

from .fbtree import FBTree, Level
from .keys import compare_padded

__all__ = ["BranchStats", "branch_level", "traverse", "to_sibling"]

_SIBLING_HOPS = 2  # bounded hops; batch ops keep parents exact so 2 suffices


class BranchStats(NamedTuple):
    feat_rounds: jnp.ndarray     # int32 [B] feature rows examined (all levels)
    suffix_bs: jnp.ndarray       # int32 [B] # of suffix binary searches taken
    key_compares: jnp.ndarray    # int32 [B] full key comparisons performed
    lines_touched: jnp.ndarray   # int32 [B] modeled 64B cache lines loaded
    sibling_hops: jnp.ndarray    # int32 [B]

    @staticmethod
    def zeros(b: int) -> "BranchStats":
        z = jnp.zeros((b,), jnp.int32)
        return BranchStats(z, z, z, z, z)

    def __add__(self, o: "BranchStats") -> "BranchStats":
        return BranchStats(*(a + b for a, b in zip(self, o)))


def _first_diff_cmp(a: jnp.ndarray, b: jnp.ndarray, nbytes: jnp.ndarray) -> jnp.ndarray:
    """3-way compare of the first ``nbytes`` bytes of a vs b. [B, L] inputs."""
    L = a.shape[-1]
    pos = jnp.arange(L, dtype=jnp.int32)
    m = pos[None, :] < nbytes[:, None]
    diff = (a.astype(jnp.int32) - b.astype(jnp.int32)) * m
    nz = diff != 0
    anynz = nz.any(-1)
    first_idx = jnp.argmax(nz, axis=-1)
    first = jnp.take_along_axis(diff, first_idx[:, None], axis=-1)[:, 0]
    return jnp.where(anynz, jnp.sign(first), 0).astype(jnp.int32)


def branch_level(level: Level, key_bytes: jnp.ndarray, key_lens: jnp.ndarray,
                 node_ids: jnp.ndarray, qb: jnp.ndarray, ql: jnp.ndarray,
                 ) -> Tuple[jnp.ndarray, BranchStats]:
    """Resolve child ids for a batch at one level. Returns (child_ids, stats)."""
    B = node_ids.shape[0]
    ns = level.features.shape[-1]
    fs = level.features.shape[-2]
    L = qb.shape[-1]
    lines_per_row = max(1, ns // 64)

    knum = level.knum[node_ids]
    plen = level.plen[node_ids]
    prefix = level.prefix[node_ids]
    feats = level.features[node_ids]          # [B, fs, ns]

    pcmp = _first_diff_cmp(qb, prefix, plen)

    lane = jnp.arange(ns, dtype=jnp.int32)[None, :]
    valid = lane < knum[:, None]              # [B, ns]
    eq = valid
    resolved = jnp.zeros((B,), bool)
    idx = jnp.zeros((B,), jnp.int32)
    feat_rounds = jnp.zeros((B,), jnp.int32)

    for fid in range(fs):
        qpos = plen + fid
        qbyte = jnp.where(
            qpos < L,
            jnp.take_along_axis(qb, jnp.clip(qpos, 0, L - 1)[:, None], axis=-1)[:, 0],
            0,
        ).astype(jnp.uint8)
        frow = feats[:, fid, :]
        m = (frow == qbyte[:, None]) & eq
        none_eq = ~m.any(-1)
        less = (frow < qbyte[:, None]) & eq
        lo = jnp.argmax(eq, axis=-1).astype(jnp.int32)
        cnt_less = less.sum(-1).astype(jnp.int32)
        res_idx = jnp.clip(lo + cnt_less - 1, 0, jnp.maximum(knum - 1, 0))
        newly = none_eq & ~resolved
        idx = jnp.where(newly, res_idx, idx)
        feat_rounds = feat_rounds + (~resolved).astype(jnp.int32)
        resolved = resolved | none_eq
        eq = jnp.where(resolved[:, None], eq, m)

    # ---- suffix binary search fallback over the surviving run ----
    # a prefix mismatch (pcmp != 0) or a trivial single-child node decides the
    # branch outright, so those lanes are not billed for the fallback — same
    # accounting as the Pallas kernel path (its `resolved` already folds both
    # in), keeping counters backend-independent.
    need_bs = ~resolved
    trivial = knum <= 1
    billed_bs = need_bs & (pcmp == 0) & ~trivial
    lo = jnp.argmax(eq, axis=-1).astype(jnp.int32)
    hi = (ns - 1 - jnp.argmax(eq[:, ::-1], axis=-1)).astype(jnp.int32)
    lo_b, hi_b = lo, hi + 1
    anchors = level.anchors[node_ids]         # [B, ns]
    n_steps = max(1, ns.bit_length())
    key_cmp = jnp.zeros((B,), jnp.int32)
    for _ in range(n_steps):
        active = lo_b < hi_b
        mid = jnp.clip((lo_b + hi_b) // 2, 0, ns - 1)
        aid = jnp.take_along_axis(anchors, mid[:, None], axis=-1)[:, 0]
        aid_safe = jnp.maximum(aid, 0)
        akb = key_bytes[aid_safe]
        akl = key_lens[aid_safe]
        c = compare_padded(akb, akl, qb, ql)  # anchor vs query
        go_right = c <= 0
        lo_b = jnp.where(active & go_right, mid + 1, lo_b)
        hi_b = jnp.where(active & ~go_right, mid, hi_b)
        key_cmp = key_cmp + (active & billed_bs).astype(jnp.int32)
    bs_idx = jnp.clip(lo_b - 1, 0, jnp.maximum(knum - 1, 0))
    idx = jnp.where(need_bs, bs_idx, idx)

    # prefix mismatch overrides feature logic entirely
    idx = jnp.where(pcmp < 0, 0, idx)
    idx = jnp.where(pcmp > 0, jnp.maximum(knum - 1, 0), idx)

    # single-child chain nodes (fixed-height artifact) are free pass-throughs:
    # a real variable-height FB+-tree has no such nodes, so they must not
    # contribute to the paper-comparable counters.
    idx = jnp.where(trivial, 0, idx)

    child = jnp.take_along_axis(level.children[node_ids], idx[:, None], axis=-1)[:, 0]

    nz = lambda x: jnp.where(trivial, 0, x).astype(jnp.int32)
    kw_lines = (ql + 63) // 64  # modeled lines per full key compare
    stats = BranchStats(
        feat_rounds=nz(feat_rounds),
        suffix_bs=billed_bs.astype(jnp.int32),
        key_compares=nz(key_cmp),
        lines_touched=nz(1 + feat_rounds * lines_per_row
                         + key_cmp * (1 + kw_lines) + 1),
        sibling_hops=jnp.zeros((B,), jnp.int32),
    )
    return child, stats


def to_sibling(tree: FBTree, leaf_ids: jnp.ndarray, qb: jnp.ndarray,
               ql: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Blink-style high-key check (§4.3): hop right while query >= high_key."""
    a = tree.arrays
    hops = jnp.zeros(leaf_ids.shape, jnp.int32)
    for _ in range(_SIBLING_HOPS):
        hk = a.leaf_high[leaf_ids]
        has_hk = hk >= 0
        hk_safe = jnp.maximum(hk, 0)
        c = compare_padded(qb, ql, a.key_bytes[hk_safe], a.key_lens[hk_safe])
        must_hop = has_hk & (c >= 0) & (a.leaf_next[leaf_ids] >= 0)
        leaf_ids = jnp.where(must_hop, a.leaf_next[leaf_ids], leaf_ids)
        hops = hops + must_hop.astype(jnp.int32)
    return leaf_ids, hops


def traverse(tree: FBTree, qb: jnp.ndarray, ql: jnp.ndarray,
             with_sibling_check: bool = True) -> Tuple[jnp.ndarray, BranchStats]:
    """Root-to-leaf traversal. Returns (leaf_ids, stats).

    Thin compatibility wrapper: the actual descent lives in
    ``core.traverse.TraversalEngine`` (imported lazily — traverse.py imports
    this module for the default backend).
    """
    from .traverse import DEFAULT_ENGINE
    leaf_ids, _, stats = DEFAULT_ENGINE.traverse(
        tree, qb, ql, sibling_check=with_sibling_check)
    return leaf_ids, stats
