"""Feature-comparison branch (paper §3.2/3.4, Fig. 6 lines 1-28), batched.

Given a batch of queries positioned at nodes of one inner level, resolve each
query's child index using:

  1. common-prefix compare (3-way);
  2. progressive byte-wise parallel feature comparison: per feature row
     ``fid`` an equality mask over all ``ns`` anchors is AND-ed into a running
     run mask; the first row with an empty intersection resolves the branch via
     a less-than mask (``compare_less`` + ``index_least1``/``countl_zero``
     become vectorized mask reductions — no scalar 64-bit packing, which suits
     the TPU VPU better than AVX mask registers);
  3. fallback binary search over anchor *suffixes* when the run survives all
     ``fs`` rows (paper line 23: prefix+feature bytes are skipped).

Everything is pure jnp so the same code is the oracle for the Pallas kernel.

Every backend takes a static ``collect_stats`` flag (threaded from
``TraversalEngine.collect_stats``, DESIGN.md §3): with it off the counter
arithmetic is never traced — backends return ``(child_ids, None)`` and the
engine substitutes zeros — so the serving/throughput path pays nothing for
the stats contract while leaf ids and paths stay bit-identical.
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from .fbtree import FBTree, Level
from .keys import compare_padded

__all__ = ["BranchStats", "branch_level", "suffix_binary_search", "traverse",
           "to_sibling"]

_SIBLING_HOPS = 2  # bounded hops; batch ops keep parents exact so 2 suffices


class BranchStats(NamedTuple):
    feat_rounds: jnp.ndarray     # int32 [B] feature rows examined (all levels)
    suffix_bs: jnp.ndarray       # int32 [B] # of suffix binary searches taken
    key_compares: jnp.ndarray    # int32 [B] full key comparisons performed
    lines_touched: jnp.ndarray   # int32 [B] modeled 64B cache lines loaded
    sibling_hops: jnp.ndarray    # int32 [B]

    @staticmethod
    def zeros(b: int) -> "BranchStats":
        z = jnp.zeros((b,), jnp.int32)
        return BranchStats(z, z, z, z, z)

    def __add__(self, o: "BranchStats") -> "BranchStats":
        return BranchStats(*(a + b for a, b in zip(self, o)))


def _first_diff_cmp(a: jnp.ndarray, b: jnp.ndarray, nbytes: jnp.ndarray) -> jnp.ndarray:
    """3-way compare of the first ``nbytes`` bytes of a vs b. [B, L] inputs."""
    L = a.shape[-1]
    pos = jnp.arange(L, dtype=jnp.int32)
    m = pos[None, :] < nbytes[:, None]
    diff = (a.astype(jnp.int32) - b.astype(jnp.int32)) * m
    nz = diff != 0
    anynz = nz.any(-1)
    first_idx = jnp.argmax(nz, axis=-1)
    first = jnp.take_along_axis(diff, first_idx[:, None], axis=-1)[:, 0]
    return jnp.where(anynz, jnp.sign(first), 0).astype(jnp.int32)


def suffix_binary_search(anchors, node_ids, key_bytes, key_lens, qb, ql, lo,
                         hi, billed, ns: int, count_compares: bool):
    """Binary search over anchor runs ``[lo, hi]``, lanes gated by ``billed``.

    ``anchors`` is the level's FULL ``[C, ns]`` table — each round gathers
    exactly one anchor id per lane (``anchors[node_ids, mid]``) instead of
    materializing the ``[B, ns]`` anchor rows up front, so a level whose
    batch never takes the fallback costs zero anchor traffic.

    Runs a ``lax.while_loop`` whose trip count is ``ceil(log2(w))`` for the
    widest *billed* run ``w`` — not a fixed ``ns.bit_length()`` unroll — so
    batches whose branches all resolve via prefix/feature compare (or land
    on trivial chain nodes) skip the compare rounds entirely. Lanes outside
    ``billed`` have their runs zeroed: their result is overridden by the
    prefix/trivial overrides downstream, so leaf ids stay bit-identical
    while the dead gathers disappear. Returns ``(lo_final, key_cmp)`` with
    ``key_cmp`` all-zero when ``count_compares`` is off.
    """
    B = lo.shape[0]
    lo_b = jnp.where(billed, lo, 0)
    hi_b = jnp.where(billed, hi + 1, 0)
    key_cmp = jnp.zeros((B,), jnp.int32)

    def cond(c):
        return (c[0] < c[1]).any()

    def body(c):
        lo_b, hi_b, key_cmp = c
        active = lo_b < hi_b
        mid = jnp.clip((lo_b + hi_b) // 2, 0, ns - 1)
        aid = anchors[node_ids, mid]             # one anchor id per lane
        aid_safe = jnp.maximum(aid, 0)
        c3 = compare_padded(key_bytes[aid_safe], key_lens[aid_safe], qb, ql)
        go_right = c3 <= 0
        lo_b = jnp.where(active & go_right, mid + 1, lo_b)
        hi_b = jnp.where(active & ~go_right, mid, hi_b)
        if count_compares:
            key_cmp = key_cmp + active.astype(jnp.int32)
        return lo_b, hi_b, key_cmp

    lo_b, _, key_cmp = jax.lax.while_loop(cond, body, (lo_b, hi_b, key_cmp))
    return lo_b, key_cmp


def branch_level(level: Level, key_bytes: jnp.ndarray, key_lens: jnp.ndarray,
                 node_ids: jnp.ndarray, qb: jnp.ndarray, ql: jnp.ndarray,
                 collect_stats: bool = True,
                 ) -> Tuple[jnp.ndarray, Optional[BranchStats]]:
    """Resolve child ids for a batch at one level. Returns (child_ids, stats);
    stats is ``None`` when ``collect_stats`` is off (the engine substitutes
    zeros — none of the counter arithmetic is traced)."""
    B = node_ids.shape[0]
    ns = level.features.shape[-1]
    fs = level.features.shape[-2]
    L = qb.shape[-1]
    lines_per_row = max(1, ns // 64)

    knum = level.knum[node_ids]

    # all-trivial short-circuit: upper chain levels of an under-full
    # fixed-height tree are single-child nodes for the WHOLE batch — the
    # feature loop, prefix compare and suffix fallback are pure dead work
    # there (idx is forced to 0, counters to 0). One reduction gates a
    # lax.cond so those levels cost a single child gather.
    def _trivial_level(_):
        child = level.children[node_ids, 0]
        return child, (BranchStats.zeros(B) if collect_stats else 0)

    def _full_level(_):
        c, s = _branch_level_full(level, key_bytes, key_lens, node_ids, knum,
                                  qb, ql, collect_stats, ns, fs, L,
                                  lines_per_row)
        return c, (s if collect_stats else 0)

    child, stats = jax.lax.cond((knum <= 1).all(), _trivial_level,
                                _full_level, None)
    return child, (stats if collect_stats else None)


def _branch_level_full(level, key_bytes, key_lens, node_ids, knum, qb, ql,
                       collect_stats, ns, fs, L, lines_per_row):
    B = node_ids.shape[0]
    plen = level.plen[node_ids]
    prefix = level.prefix[node_ids]
    feats = level.features[node_ids]          # [B, fs, ns]

    pcmp = _first_diff_cmp(qb, prefix, plen)

    lane = jnp.arange(ns, dtype=jnp.int32)[None, :]
    valid = lane < knum[:, None]              # [B, ns]
    eq = valid
    resolved = jnp.zeros((B,), bool)
    idx = jnp.zeros((B,), jnp.int32)
    feat_rounds = jnp.zeros((B,), jnp.int32)

    for fid in range(fs):
        qpos = plen + fid
        qbyte = jnp.where(
            qpos < L,
            jnp.take_along_axis(qb, jnp.clip(qpos, 0, L - 1)[:, None], axis=-1)[:, 0],
            0,
        ).astype(jnp.uint8)
        frow = feats[:, fid, :]
        m = (frow == qbyte[:, None]) & eq
        none_eq = ~m.any(-1)
        less = (frow < qbyte[:, None]) & eq
        lo = jnp.argmax(eq, axis=-1).astype(jnp.int32)
        cnt_less = less.sum(-1).astype(jnp.int32)
        res_idx = jnp.clip(lo + cnt_less - 1, 0, jnp.maximum(knum - 1, 0))
        newly = none_eq & ~resolved
        idx = jnp.where(newly, res_idx, idx)
        if collect_stats:
            feat_rounds = feat_rounds + (~resolved).astype(jnp.int32)
        resolved = resolved | none_eq
        eq = jnp.where(resolved[:, None], eq, m)

    # ---- suffix binary search fallback over the surviving run ----
    # a prefix mismatch (pcmp != 0) or a trivial single-child node decides the
    # branch outright, so those lanes are not billed for the fallback — same
    # accounting as the Pallas kernel path (its `resolved` already folds both
    # in), keeping counters backend-independent. Unbilled lanes also skip the
    # search itself (suffix_binary_search zeroes their runs): their fallback
    # result is unconditionally overridden below, so the skip is free.
    need_bs = ~resolved
    trivial = knum <= 1
    billed_bs = need_bs & (pcmp == 0) & ~trivial
    lo = jnp.argmax(eq, axis=-1).astype(jnp.int32)
    hi = (ns - 1 - jnp.argmax(eq[:, ::-1], axis=-1)).astype(jnp.int32)
    lo_b, key_cmp = suffix_binary_search(
        level.anchors, node_ids, key_bytes, key_lens, qb, ql, lo, hi,
        billed_bs, ns, count_compares=collect_stats)
    bs_idx = jnp.clip(lo_b - 1, 0, jnp.maximum(knum - 1, 0))
    idx = jnp.where(billed_bs, bs_idx, idx)

    # prefix mismatch overrides feature logic entirely
    idx = jnp.where(pcmp < 0, 0, idx)
    idx = jnp.where(pcmp > 0, jnp.maximum(knum - 1, 0), idx)

    # single-child chain nodes (fixed-height artifact) are free pass-throughs:
    # a real variable-height FB+-tree has no such nodes, so they must not
    # contribute to the paper-comparable counters.
    idx = jnp.where(trivial, 0, idx)

    # one child id per lane — not the [B, ns] row gather the take_along_axis
    # formulation forced
    child = level.children[node_ids, idx]

    if not collect_stats:
        return child, None
    nz = lambda x: jnp.where(trivial, 0, x).astype(jnp.int32)
    kw_lines = (ql + 63) // 64  # modeled lines per full key compare
    stats = BranchStats(
        feat_rounds=nz(feat_rounds),
        suffix_bs=billed_bs.astype(jnp.int32),
        key_compares=nz(key_cmp),
        lines_touched=nz(1 + feat_rounds * lines_per_row
                         + key_cmp * (1 + kw_lines) + 1),
        sibling_hops=jnp.zeros((B,), jnp.int32),
    )
    return child, stats


def to_sibling(tree: FBTree, leaf_ids: jnp.ndarray, qb: jnp.ndarray,
               ql: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Blink-style high-key check (§4.3): hop right while query >= high_key."""
    a = tree.arrays
    hops = jnp.zeros(leaf_ids.shape, jnp.int32)
    for _ in range(_SIBLING_HOPS):
        hk = a.leaf_high[leaf_ids]
        has_hk = hk >= 0
        hk_safe = jnp.maximum(hk, 0)
        c = compare_padded(qb, ql, a.key_bytes[hk_safe], a.key_lens[hk_safe])
        must_hop = has_hk & (c >= 0) & (a.leaf_next[leaf_ids] >= 0)
        leaf_ids = jnp.where(must_hop, a.leaf_next[leaf_ids], leaf_ids)
        hops = hops + must_hop.astype(jnp.int32)
    return leaf_ids, hops


def traverse(tree: FBTree, qb: jnp.ndarray, ql: jnp.ndarray,
             with_sibling_check: bool = True) -> Tuple[jnp.ndarray, BranchStats]:
    """Root-to-leaf traversal. Returns (leaf_ids, stats).

    Thin compatibility wrapper: the actual descent lives in
    ``core.traverse.TraversalEngine`` (imported lazily — traverse.py imports
    this module for the default backend).
    """
    from .traverse import DEFAULT_ENGINE
    leaf_ids, _, stats = DEFAULT_ENGINE.traverse(
        tree, qb, ql, sibling_check=with_sibling_check)
    return leaf_ids, stats
