from . import checkpoint, data, ft, losses, optim, train_step  # noqa: F401
