"""Fault tolerance: straggler watchdog, failure injection, restartable loop.

On a real fleet the coordinator restarts failed workers from the latest
checkpoint; here the same control flow is exercised in-process:
``run_with_restarts`` drives a step function, catches (injected or real)
worker failures, restores from the newest checkpoint — possibly onto a
*different* mesh (elastic rescale) — and continues. The watchdog flags
straggling steps by robust z-score over a rolling window (on TPU fleets this
is the signal that triggers hot-spare swap / re-slicing).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional

import numpy as np


class WorkerFailure(RuntimeError):
    """Raised by failure injection (or wrapped real errors)."""


@dataclasses.dataclass
class Watchdog:
    window: int = 32
    z_thresh: float = 4.0
    durations: List[float] = dataclasses.field(default_factory=list)
    stragglers: List[Dict] = dataclasses.field(default_factory=list)

    def observe(self, step: int, dt: float) -> bool:
        """Record a step duration; returns True if it straggles."""
        hist = self.durations[-self.window:]
        self.durations.append(dt)
        if len(hist) < 8:
            return False
        med = float(np.median(hist))
        mad = float(np.median(np.abs(np.asarray(hist) - med))) + 1e-9
        z = (dt - med) / (1.4826 * mad)
        if z > self.z_thresh:
            self.stragglers.append({"step": step, "dt": dt, "z": z})
            return True
        return False


@dataclasses.dataclass
class FailurePlan:
    """Deterministic injected failures: {step: kind}."""
    at_steps: Dict[int, str] = dataclasses.field(default_factory=dict)

    def check(self, step: int):
        kind = self.at_steps.get(step)
        if kind:
            del self.at_steps[step]
            raise WorkerFailure(f"injected {kind} at step {step}")


def run_with_restarts(total_steps: int,
                      make_runner: Callable[[int], Callable[[int], float]],
                      save_every: int,
                      saver: Callable[[int], None],
                      restorer: Callable[[], int],
                      max_failures: int = 8,
                      watchdog: Optional[Watchdog] = None) -> Dict:
    """Drive steps with checkpoint/restart semantics.

    make_runner(start_step) -> step_fn(step)->loss  (rebuilds state from the
    latest checkpoint — the restart path re-enters here, which is where an
    elastic deployment would also rebuild the mesh).
    """
    failures = 0
    step = restorer()
    runner = make_runner(step)
    log = {"restarts": [], "losses": {}}
    while step < total_steps:
        try:
            t0 = time.perf_counter()
            loss = runner(step)
            dt = time.perf_counter() - t0
            if watchdog is not None:
                watchdog.observe(step, dt)
            log["losses"][step] = float(loss)
            step += 1
            if step % save_every == 0:
                saver(step)
        except WorkerFailure as e:
            failures += 1
            if failures > max_failures:
                raise
            log["restarts"].append({"step": step, "err": str(e)})
            step = restorer()
            runner = make_runner(step)
    return log
