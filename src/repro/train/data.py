"""Deterministic, seekable synthetic data pipeline.

Every batch is a pure function of (seed, step, shard_id) via Philox counters,
so restarts — including *elastic* restarts onto a different data-shard count —
reproduce the exact global token stream (fault-tolerance requirement).
The token distribution is a two-level Markov-ish mixture over a zipfian
vocabulary: structured enough for a ~100M model to visibly learn in a few
hundred steps, cheap enough to generate at line rate on host CPUs.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import numpy as np

from repro.models.config import ModelConfig


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    global_batch: int
    seq_len: int
    seed: int = 0
    zipf_a: float = 1.3
    n_states: int = 64           # markov states


def _rng(cfg: DataConfig, step: int, stream: int) -> np.random.Generator:
    k0 = np.uint64((cfg.seed * 0x9E3779B97F4A7C15 + stream + 1) % 2**64)
    k1 = np.uint64(step + 2)
    return np.random.Generator(np.random.Philox(key=[k0, k1]))


def _zipf_probs(cfg: DataConfig) -> np.ndarray:
    r = np.arange(1, cfg.vocab + 1, dtype=np.float64)
    p = r ** (-cfg.zipf_a)
    return p / p.sum()


class TokenStream:
    """Seekable batch source: ``batch_at(step)`` is stateless."""

    def __init__(self, cfg: DataConfig, model_cfg: Optional[ModelConfig] = None):
        self.cfg = cfg
        self.model_cfg = model_cfg
        base = _rng(cfg, -1, 0)
        # per-state token tables: each markov state prefers a band of tokens
        self._state_shift = base.integers(0, cfg.vocab, size=cfg.n_states)
        self._trans = base.integers(0, cfg.n_states,
                                    size=(cfg.n_states, 4))

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        g = _rng(cfg, step, 0)
        B, S = cfg.global_batch, cfg.seq_len
        # zipf draws + per-position state shift (adds learnable structure)
        u = g.random((B, S))
        toks = np.minimum((u ** (-1.0 / (cfg.zipf_a - 1.0))).astype(np.int64),
                          cfg.vocab - 1)
        states = np.zeros((B,), np.int64)
        shift = np.empty((B, S), np.int64)
        for t in range(0, S, 64):          # state evolves per 64-token block
            shift[:, t:t + 64] = self._state_shift[states][:, None]
            states = self._trans[states, g.integers(0, 4, size=B)]
        toks = ((toks + shift) % cfg.vocab).astype(np.int32)
        batch = {"tokens": toks}
        mc = self.model_cfg
        if mc is not None and mc.family == "vlm":
            batch["patches"] = g.standard_normal(
                (B, mc.n_patches, mc.frontend_dim)).astype(np.float32)
        if mc is not None and mc.family == "encdec":
            batch["frames"] = g.standard_normal(
                (B, S, mc.frontend_dim)).astype(np.float32)
        return batch

    def shard_batch_at(self, step: int, shard_id: int, n_shards: int):
        """The shard_id-th slice of the global batch (host-local loading on a
        real fleet; sliced from the deterministic global stream so any
        (shard_id, n_shards) factorization yields the same global data)."""
        full = self.batch_at(step)
        B = self.cfg.global_batch
        assert B % n_shards == 0
        per = B // n_shards
        return {k: v[shard_id * per:(shard_id + 1) * per] for k, v in
                full.items()}

    def iter_from(self, step: int) -> Iterator[Dict[str, np.ndarray]]:
        while True:
            yield self.batch_at(step)
            step += 1
