"""Step-atomic, async, elastic checkpointing.

Layout:  <dir>/step_<N>/  shards.npz  manifest.json   (+ tmp dir until
atomic rename). The manifest records tree paths, shapes, dtypes so restore
validates structure. ``restore`` device_puts every tensor with the *target*
mesh's shardings — restoring onto a different mesh shape (elastic rescale)
is the same code path. Keep-k GC; an async writer thread keeps the train
loop running during serialization.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p))))
                       for p in path)
        arr = np.asarray(leaf)
        if arr.dtype.name == "bfloat16":      # npz has no bf16 cast path
            arr = arr.astype(np.float32)
        flat[key] = arr
    return flat


def save(ckpt_dir: str, step: int, state, meta: Optional[Dict] = None,
         keep: int = 3, async_: bool = False) -> threading.Thread:
    """Write checkpoint for ``step``. Returns the writer thread (joined if
    sync)."""
    flat = _flatten(state)   # host copy happens on the caller thread (safe)

    def _write():
        final = os.path.join(ckpt_dir, f"step_{step:08d}")
        tmp = final + ".tmp"
        os.makedirs(tmp, exist_ok=True)
        np.savez(os.path.join(tmp, "shards.npz"), **flat)
        manifest = {
            "step": step,
            "time": time.time(),
            "tensors": {k: {"shape": list(v.shape), "dtype": str(v.dtype)}
                        for k, v in flat.items()},
            "meta": meta or {},
        }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f, indent=1)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        _gc(ckpt_dir, keep)

    t = threading.Thread(target=_write, daemon=True)
    t.start()
    if not async_:
        t.join()
    return t


def _gc(ckpt_dir: str, keep: int):
    steps = sorted(d for d in os.listdir(ckpt_dir) if d.startswith("step_")
                   and not d.endswith(".tmp"))
    for d in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, d), ignore_errors=True)


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(ckpt_dir)
             if d.startswith("step_") and not d.endswith(".tmp")]
    return max(steps) if steps else None


def restore(ckpt_dir: str, template, step: Optional[int] = None,
            shardings=None) -> Tuple[Any, int]:
    """Restore into the structure of ``template``; device_put with
    ``shardings`` (a matching pytree or None). Elastic: shardings may come
    from a different mesh than the one that saved."""
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {ckpt_dir}")
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(d, "shards.npz"))
    paths, treedef = jax.tree_util.tree_flatten_with_path(template)
    shard_leaves = (jax.tree_util.tree_leaves(shardings)
                    if shardings is not None else [None] * len(paths))
    leaves = []
    for (path, leaf), sh in zip(paths, shard_leaves):
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p))))
                       for p in path)
        if key not in data:
            raise KeyError(f"checkpoint missing tensor {key}")
        arr = data[key]
        want = tuple(np.shape(leaf))
        if tuple(arr.shape) != want:
            raise ValueError(f"{key}: checkpoint shape {arr.shape} != {want}")
        want_dtype = getattr(leaf, "dtype", arr.dtype)
        ja = jax.numpy.asarray(arr).astype(want_dtype)
        leaves.append(jax.device_put(ja, sh) if sh is not None
                      else jax.device_put(ja))
    return jax.tree_util.tree_unflatten(treedef, leaves), step
