"""Optimizers (hand-rolled; no optax offline): AdamW and Adafactor, with
global-norm clipping, cosine schedule with warmup, and ZeRO-style sharded
optimizer states.

AdamW keeps f32 (m, v) + f32 master copies when params are bf16 (mixed
precision). Adafactor keeps factored second moments only (row/col) — the
memory plan that lets the 671B config fit 512 chips.
State sharding: each state tensor inherits its param's spec; ZeRO-1
additionally shards a free dim over "data" when divisible (zero_spec).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class OptConfig:
    kind: str = "adamw"          # adamw | adafactor
    lr: float = 3e-4
    warmup: int = 100
    total_steps: int = 10_000
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    min_lr_frac: float = 0.1


def schedule(cfg: OptConfig, step):
    s = step.astype(jnp.float32)
    warm = jnp.minimum(s / jnp.maximum(cfg.warmup, 1), 1.0)
    t = jnp.clip((s - cfg.warmup) / jnp.maximum(cfg.total_steps - cfg.warmup, 1),
                 0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * t))
    frac = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * cos
    return cfg.lr * warm * frac


def clip_by_global_norm(grads, max_norm: float):
    leaves = jax.tree_util.tree_leaves(grads)
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                      for g in leaves))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return jax.tree_util.tree_map(
        lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), grads), gn


# ------------------------------------------------------------------- adamw
def adamw_init(params):
    f32 = lambda p: jnp.zeros_like(p, dtype=jnp.float32)
    return {"m": jax.tree_util.tree_map(f32, params),
            "v": jax.tree_util.tree_map(f32, params),
            "master": jax.tree_util.tree_map(
                lambda p: p.astype(jnp.float32), params),
            "step": jnp.zeros((), jnp.int32)}


def adamw_update(cfg: OptConfig, params, grads, state):
    step = state["step"] + 1
    lr = schedule(cfg, step)
    grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v, master):
        g = g.astype(jnp.float32)
        m2 = b1 * m + (1 - b1) * g
        v2 = b2 * v + (1 - b2) * g * g
        u = (m2 / bc1) / (jnp.sqrt(v2 / bc2) + cfg.eps)
        if p.ndim >= 2:   # decoupled decay on matrices only
            u = u + cfg.weight_decay * master
        master2 = master - lr * u
        return master2.astype(p.dtype), m2, v2, master2

    out = jax.tree_util.tree_map(upd, params, grads, state["m"], state["v"],
                                 state["master"])
    new_p = jax.tree_util.tree_map(lambda t: t[0], out,
                                   is_leaf=lambda t: isinstance(t, tuple))
    new_m = jax.tree_util.tree_map(lambda t: t[1], out,
                                   is_leaf=lambda t: isinstance(t, tuple))
    new_v = jax.tree_util.tree_map(lambda t: t[2], out,
                                   is_leaf=lambda t: isinstance(t, tuple))
    new_master = jax.tree_util.tree_map(lambda t: t[3], out,
                                        is_leaf=lambda t: isinstance(t, tuple))
    return new_p, {"m": new_m, "v": new_v, "master": new_master,
                   "step": step}, {"grad_norm": gnorm, "lr": lr}


# --------------------------------------------------------------- adafactor
def adafactor_init(params):
    def rows(p):
        if p.ndim >= 2:
            return jnp.zeros(p.shape[:-1], jnp.float32)
        return jnp.zeros(p.shape, jnp.float32)

    def cols(p):
        if p.ndim >= 2:
            return jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)
        return jnp.zeros((1,), jnp.float32)

    return {"vr": jax.tree_util.tree_map(rows, params),
            "vc": jax.tree_util.tree_map(cols, params),
            "step": jnp.zeros((), jnp.int32)}


def adafactor_update(cfg: OptConfig, params, grads, state):
    step = state["step"] + 1
    lr = schedule(cfg, step)
    grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    beta = 1.0 - (step.astype(jnp.float32) ** -0.8)

    def upd(p, g, vr, vc):
        g = g.astype(jnp.float32)
        g2 = g * g + 1e-30
        if p.ndim >= 2:
            vr2 = beta * vr + (1 - beta) * g2.mean(-1)
            vc2 = beta * vc + (1 - beta) * g2.mean(-2)
            denom = (vr2[..., None] * vc2[..., None, :]
                     / jnp.maximum(vr2.mean(-1)[..., None, None], 1e-30))
            u = g * jax.lax.rsqrt(denom + 1e-30)
        else:
            vr2 = beta * vr + (1 - beta) * g2
            vc2 = vc
            u = g * jax.lax.rsqrt(vr2 + 1e-30)
        # update clipping (Adafactor d=1.0)
        rms_u = jnp.sqrt(jnp.mean(u * u) + 1e-30)
        u = u / jnp.maximum(1.0, rms_u)
        pf = p.astype(jnp.float32)
        if p.ndim >= 2:
            pf = pf * (1 - lr * cfg.weight_decay)
        return (pf - lr * u).astype(p.dtype), vr2, vc2

    out = jax.tree_util.tree_map(upd, params, grads, state["vr"], state["vc"])
    pick = lambda i: jax.tree_util.tree_map(
        lambda t: t[i], out, is_leaf=lambda t: isinstance(t, tuple))
    return pick(0), {"vr": pick(1), "vc": pick(2), "step": step}, \
        {"grad_norm": gnorm, "lr": lr}


# ------------------------------------------------------------------ facade
def opt_init(cfg: OptConfig, params):
    return adamw_init(params) if cfg.kind == "adamw" else adafactor_init(params)


def opt_update(cfg: OptConfig, params, grads, state):
    if cfg.kind == "adamw":
        return adamw_update(cfg, params, grads, state)
    return adafactor_update(cfg, params, grads, state)


# ---------------------------------------------------------------- ZeRO spec
def zero_spec(pspec: P, shape: Tuple[int, ...], mesh: Mesh) -> P:
    """ZeRO-1: shard one free dim of an optimizer-state tensor over 'data'."""
    dp = mesh.shape.get("data", 1) if "data" in mesh.axis_names else 1
    entries = list(pspec) + [None] * (len(shape) - len(pspec))
    for e in entries:                 # FSDP already shards over 'data'
        names = e if isinstance(e, tuple) else (e,)
        if "data" in names:
            return P(*entries)
    for i, (e, n) in enumerate(zip(entries, shape)):
        if e is None and dp > 1 and n % dp == 0 and n >= dp:
            entries[i] = "data"
            break
    return P(*entries)


def opt_state_shardings(state, param_shardings, mesh: Mesh, zero1: bool = True):
    """Shardings for the optimizer-state tree. m/v/master mirror params
    (+ZeRO); factored vr/vc and scalars follow shape-based rules."""
    pshard_by_struct = {}

    def like_param(sub):
        def one(ps, leaf):
            spec = ps.spec
            if zero1:
                spec = zero_spec(spec, np.shape(leaf), mesh)
            return NamedSharding(mesh, spec)
        return jax.tree_util.tree_map(one, param_shardings, sub)

    out = {}
    for k, sub in state.items():
        if k == "step":
            out[k] = NamedSharding(mesh, P())
        elif k in ("m", "v", "master"):
            out[k] = like_param(sub)
        else:  # vr / vc — factored: replicate (small) unless dim divisible
            def one(leaf):
                shape = np.shape(leaf)
                spec = zero_spec(P(), shape, mesh) if zero1 else P()
                return NamedSharding(mesh, spec)
            out[k] = jax.tree_util.tree_map(one, sub)
    return out
