"""Train step: value_and_grad + microbatch accumulation + optimizer update.

Microbatching (grad accumulation) runs as a ``lax.scan`` over microbatch
slices with an f32 grad accumulator; because each microbatch's backward ends
in reduce-scatter-able contributions, XLA overlaps the collectives of
microbatch *i* with the compute of microbatch *i+1* (see
comm/compute overlap knob, exercised in §Perf). Optional int8+error-feedback
gradient compression plugs in between accumulation and the update.
"""
from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.parallel import compression as C
from repro.train import optim as O
from repro.train.losses import loss_fn


def init_state(cfg: ModelConfig, opt_cfg: O.OptConfig, key):
    from repro.models import lm
    params = lm.init_params(cfg, key)
    return {"params": params, "opt": O.opt_init(opt_cfg, params)}


def make_train_step(cfg: ModelConfig, opt_cfg: O.OptConfig, shard=None,
                    n_micro: int = 1, compress: bool = False):
    """Returns f(state, batch) -> (state', metrics). Pure — jit at call site."""
    if shard is None:
        from repro.models.lm import NOSHARD as shard  # noqa

    def grads_of(params, batch):
        (l, m), g = jax.value_and_grad(
            lambda p: loss_fn(p, cfg, batch, shard), has_aux=True)(params)
        return g, m

    def step(state, batch):
        params = state["params"]
        if n_micro == 1:
            grads, metrics = grads_of(params, batch)
        else:
            def micro(acc, mb):
                g, m = grads_of(params, mb)
                acc = jax.tree_util.tree_map(
                    lambda a, b: a + b.astype(jnp.float32), acc, g)
                return acc, m
            acc0 = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            mbs = jax.tree_util.tree_map(
                lambda x: x.reshape((n_micro, x.shape[0] // n_micro)
                                    + x.shape[1:]), batch)
            grads, ms = jax.lax.scan(micro, acc0, mbs)
            grads = jax.tree_util.tree_map(lambda g: g / n_micro, grads)
            metrics = jax.tree_util.tree_map(lambda a: a[-1], ms)
        if compress:
            eb = state.get("error_fb")
            if eb is None:
                eb = jax.tree_util.tree_map(
                    lambda g: jnp.zeros(g.shape, jnp.float32), grads)
            grads, eb = C.compress_grads_ef(grads, eb)
            state = dict(state, error_fb=eb)
        new_params, new_opt, om = O.opt_update(opt_cfg, params, grads,
                                               state["opt"])
        metrics = dict(metrics, **om)
        return dict(state, params=new_params, opt=new_opt), metrics

    return step


def make_eval_step(cfg: ModelConfig, shard=None):
    if shard is None:
        from repro.models.lm import NOSHARD as shard  # noqa

    def step(params, batch):
        _, metrics = loss_fn(params, cfg, batch, shard)
        return metrics
    return step
