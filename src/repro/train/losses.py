"""Loss assembly per family: shifted-token CE + MoE aux + DeepSeek MTP."""
from __future__ import annotations

from typing import Dict, Tuple

import jax.numpy as jnp

from repro.models import lm
from repro.models.config import ModelConfig
from repro.models.layers import softmax_xent

AUX_COEF = 0.01


def loss_fn(params, cfg: ModelConfig, batch: Dict, shard=lm.NOSHARD,
            ) -> Tuple[jnp.ndarray, Dict]:
    logits, aux, hidden = lm.forward(params, cfg, batch, shard)
    tokens = batch["tokens"]
    if cfg.family == "vlm":        # text positions only
        logits_txt = logits[:, cfg.n_patches:]
        ce = softmax_xent(logits_txt[:, :-1], tokens[:, 1:])
    else:
        ce = softmax_xent(logits[:, :-1], tokens[:, 1:])
    loss = ce + AUX_COEF * aux
    metrics = {"ce": ce, "aux": aux}
    if cfg.mtp:
        mlogits = lm.mtp_logits(params, cfg, hidden, tokens, shard)
        mtp_ce = softmax_xent(mlogits[:, :-2], tokens[:, 2:])
        loss = loss + cfg.mtp_weight * mtp_ce
        metrics["mtp_ce"] = mtp_ce
    metrics["loss"] = loss
    return loss, metrics
