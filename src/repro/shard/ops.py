"""Routed batch ops over a ShardedTree (DESIGN.md §7).

Dispatch model: the router buckets the query batch by owning shard, then a
host loop launches ONE jitted shard-local op per shard that owns work —
the same ``core.batch_ops`` entry points every unsharded call site uses,
through the same ``TraversalEngine`` (any backend/layout, including the
fused kernels). Launches are asynchronous per device, so with a
multi-device mesh the shards genuinely overlap; results are combined
host-side by owner select.

Shapes stay static by running each shard over the *full* batch with the
routed-op ``mask`` hook (``core.batch_ops``): masked-out lanes read
harmlessly and never write, so a shard-local op on a full batch commits
exactly its owned lanes. Shards owning no lanes are skipped outright.

Cross-shard ``range_scan``: each query starts in its owner shard; lanes
that exhaust the owner's leaf chain before ``max_items`` spill to the next
shard (range partition ⇒ the next shard's first key is the chain's
successor) and the per-shard emissions — each ascending, each riding the
§6 lazy-rearrangement fast path — concatenate in shard order into the
globally ascending result. Filled lanes are parked on an all-0xFF start
key so later shards do one trivial descent for them, and the host loop
stops as soon as no lane is active.

Fault tolerance (DESIGN.md §8): every launch goes through
:func:`_dispatch`, which retries injected :class:`ShardDropped` faults
with capped exponential backoff and marks a shard unhealthy
(``ShardedTree.health``) when retries are exhausted. Unhealthy shards
degrade instead of erroring: lookups serve their lanes from the
last-barrier ``snapshots`` replica (``degraded`` mask — possibly stale),
mutations and scans report those lanes ``failed`` (never silently
dropped or truncated), and :func:`rebalance` is the recovery barrier that
re-admits the shard with fresh health and snapshots.
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.core import batch_ops as B
from repro.core import keys as K
from repro.core.faults import FaultPlan, RetryPolicy, ShardDropped
from repro.core.fbtree import EMPTY
from repro.core.traverse import TraversalEngine

from .build import sharded_build
from .router import route
from .tree import ShardedTree

__all__ = ["ShardOpReport", "RebalanceReport", "lookup_batch",
           "update_batch", "insert_batch", "remove_batch", "range_scan",
           "rebalance", "DEFAULT_RETRY"]

DEFAULT_RETRY = RetryPolicy()


class ShardOpReport(NamedTuple):
    """Cross-shard op outcome (host numpy — produced after the combine).

    A shard ends a routed op in exactly one of three states, and the
    report keeps them apart (healthy skips must never read as
    degradation — the telemetry counters and recovery heuristics key off
    this): **hit** (owned lanes, served normally), **skipped** (owned no
    lanes this batch — healthy, no launch attempted), or **dropped**
    (owned lanes but was unreachable: its lanes appear in ``degraded``
    for lookups or ``failed`` for mutations/scans).
    """
    found: np.ndarray       # bool [B] — owner shard's found
    conflicts: np.ndarray   # int32 — in-batch dedupe losers (global, once)
    splits: np.ndarray      # int32 — leaf splits summed over shards
    error: np.ndarray       # bool — any shard hit a capacity error
    owner: np.ndarray       # int32 [B] — routed shard per query
    shards_hit: int         # shards that owned lanes AND served normally
    failed: np.ndarray = np.zeros(0, bool)    # bool [B] — lane not served
    #                         (owner shard down; mutations: NOT committed)
    degraded: np.ndarray = np.zeros(0, bool)  # bool [B] — lane served from
    #                         the last-barrier snapshot (may be stale)
    shards_skipped: int = 0  # healthy shards that owned no lanes
    shards_dropped: Tuple[int, ...] = ()      # shard ids unreachable this
    #                         op (their lanes are degraded/failed above)


class RebalanceReport(NamedTuple):
    """Outcome of a cross-shard rebalance (a bulk-synchronous barrier)."""
    n_live: int             # keys carried into the new partition
    reclaimed: int          # key-pool rows freed across shards
    counts_before: Tuple[int, ...]   # live keys per shard pre-barrier
    counts_after: Tuple[int, ...]    # live keys per shard post-barrier


def _put(x, dev):
    return x if dev is None else jax.device_put(x, dev)


def _owner_masks(st: ShardedTree, qb, ql):
    """Route once; per-shard owner masks as host bools."""
    qb = jnp.asarray(qb)
    if qb.ndim != 2 or qb.shape[-1] != st.config.key_width:
        got = "x".join(map(str, qb.shape))
        raise ValueError(
            f"query batch shape [{got}] does not match the tree's key "
            f"width {st.config.key_width}: routing compares packed words, "
            f"so keys must be zero-padded to exactly key_width bytes — "
            f"build them with repro.core.keys.make_keyset(keys, "
            f"max_key_len={st.config.key_width})")
    ql = jnp.asarray(ql)
    owner = np.asarray(route(st.router, qb, ql))
    return qb, ql, owner


def _dispatch(st: ShardedTree, s: int, opname: str, call,
              faults: Optional[FaultPlan], retry: Optional[RetryPolicy]):
    """Launch one shard-local op through the fault layer.

    Returns the op result, or None when the shard cannot be reached: the
    site ``shard.dispatch.<opname>`` fires per attempt; ShardDropped is
    retried with capped exponential backoff (transient flakes are
    absorbed); exhausting retries marks the shard down in
    ``st.health`` so later ops skip the launch outright. Any other
    exception (capacity overflow etc.) propagates unchanged — faults
    model reachability, not data errors.
    """
    if st.health is not None and not st.health.is_ok(s):
        obs.counter("shard.skipped_down", op=opname).inc()
        return None
    pol = retry if retry is not None else DEFAULT_RETRY
    delays = list(pol.delays()) + [None]        # None = no sleep after last
    with obs.span("shard.dispatch", op=opname, shard=s):
        for attempt, delay in enumerate(delays):
            try:
                if faults is not None:
                    faults.fire(f"shard.dispatch.{opname}", shard=s,
                                attempt=attempt)
                return call()
            except ShardDropped:
                obs.counter("shard.retries", op=opname).inc()
                obs.event("shard.retry", op=opname, shard=s,
                          attempt=attempt)
                if delay is not None:
                    pol.sleep(delay)
    if st.health is not None:
        st.health.mark_down(
            s, f"{opname}: unreachable after {len(delays)} attempts")
    obs.counter("shard.down", op=opname).inc()
    obs.event("shard.down", op=opname, shard=s, attempts=len(delays))
    return None


def lookup_batch(st: ShardedTree, qb, ql,
                 engine: Optional[TraversalEngine] = None,
                 faults: Optional[FaultPlan] = None,
                 retry: Optional[RetryPolicy] = None):
    """Batched point lookup across shards. Returns ``(vals [B], report)``;
    ``vals``/``found`` are bit-identical to ``core.batch_ops.lookup_batch``
    on one unsharded tree over the same keys.

    Degradation: lanes owned by an unreachable shard are served from that
    shard's last-barrier snapshot (``report.degraded`` — correct as of the
    barrier, possibly stale) rather than failed; reads prefer staleness
    over unavailability. ``report.failed`` stays all-False for lookups.
    """
    qb, ql, owner = _owner_masks(st, qb, ql)
    Bn = qb.shape[0]
    vals = np.zeros((Bn,), dtype=np.asarray(
        jnp.zeros((), st.config.val_dtype)).dtype)
    found = np.zeros((Bn,), dtype=bool)
    degraded = np.zeros((Bn,), dtype=bool)
    pending = []
    hit = 0
    skipped = 0
    dropped = []
    for s, t in enumerate(st.shards):
        sel = owner == s
        if not sel.any():
            skipped += 1                        # healthy skip, not a drop
            continue
        dev = st.devices[s]
        res = _dispatch(
            st, s, "lookup",
            lambda: B.lookup_batch(t, _put(qb, dev), _put(ql, dev),
                                   engine=engine),
            faults, retry)
        if res is None:
            # degrade: the snapshot replica is reachable by construction
            # (it lives with the router, not behind the downed dispatch)
            snap = st.snapshots[s]
            v, rep = B.lookup_batch(snap, qb, ql, engine=engine)
            degraded |= sel
            dropped.append(s)
            obs.counter("shard.degraded_lanes", op="lookup").inc(
                int(sel.sum()))
            obs.event("shard.degraded", op="lookup", shard=s,
                      lanes=int(sel.sum()))
        else:
            v, rep = res
            hit += 1
        pending.append((sel, v, rep.found))     # async: combine later
    for sel, v, f in pending:
        vals[sel] = np.asarray(v)[sel]
        found[sel] = np.asarray(f)[sel]
    rep = ShardOpReport(found=found, conflicts=np.int32(0),
                        splits=np.int32(0), error=np.bool_(False),
                        owner=owner, shards_hit=hit,
                        failed=np.zeros((Bn,), bool), degraded=degraded,
                        shards_skipped=skipped,
                        shards_dropped=tuple(dropped))
    return vals, rep


def _routed_mutation(st: ShardedTree, owner, opname, run_one, faults,
                     retry):
    """Shared mutation loop: run ``run_one(shard_tree, mask, dev)`` on every
    reachable shard owning lanes; returns (new shards, outcomes, failed,
    skipped, dropped).

    Lanes of an unreachable shard are reported ``failed`` — the shard tree
    is left untouched (the mutation is NOT committed there), so a caller
    can re-apply exactly the failed lanes after recovery.
    """
    shards = list(st.shards)
    outcomes = []
    failed = np.zeros(owner.shape, dtype=bool)
    skipped = 0
    dropped = []
    for s, t in enumerate(st.shards):
        sel = owner == s
        if not sel.any():
            skipped += 1                        # healthy skip, not a drop
            continue
        dev = st.devices[s]

        def call(t=t, sel=sel, dev=dev):
            mask = _put(jnp.asarray(sel), dev)
            return run_one(t, mask, dev)
        res = _dispatch(st, s, opname, call, faults, retry)
        if res is None:
            failed |= sel
            dropped.append(s)
            obs.counter("shard.failed_lanes", op=opname).inc(int(sel.sum()))
            obs.event("shard.failed", op=opname, shard=s,
                      lanes=int(sel.sum()))
            continue
        t2, out = res
        shards[s] = t2
        outcomes.append((sel, out))
    return tuple(shards), outcomes, failed, skipped, tuple(dropped)


def update_batch(st: ShardedTree, qb, ql, vals,
                 engine: Optional[TraversalEngine] = None,
                 faults: Optional[FaultPlan] = None,
                 retry: Optional[RetryPolicy] = None):
    """Routed blind update. Returns ``(ShardedTree', report)``."""
    qb, ql, owner = _owner_masks(st, qb, ql)
    vals = jnp.asarray(vals)

    def run_one(t, mask, dev):
        t2, rep = B.update_batch(t, _put(qb, dev), _put(ql, dev),
                                 _put(vals, dev), engine=engine, mask=mask)
        return t2, rep
    shards, outcomes, failed, skipped, dropped = _routed_mutation(
        st, owner, "update", run_one, faults, retry)
    return (st.replace(shards=shards),
            _combine(outcomes, owner, failed, skipped, dropped))


def remove_batch(st: ShardedTree, qb, ql,
                 engine: Optional[TraversalEngine] = None,
                 faults: Optional[FaultPlan] = None,
                 retry: Optional[RetryPolicy] = None):
    """Routed tombstone removal. Returns ``(ShardedTree', report)``."""
    qb, ql, owner = _owner_masks(st, qb, ql)

    def run_one(t, mask, dev):
        t2, rep = B.remove_batch(t, _put(qb, dev), _put(ql, dev),
                                 engine=engine, mask=mask)
        return t2, rep
    shards, outcomes, failed, skipped, dropped = _routed_mutation(
        st, owner, "remove", run_one, faults, retry)
    return (st.replace(shards=shards),
            _combine(outcomes, owner, failed, skipped, dropped))


def insert_batch(st: ShardedTree, qb, ql, vals,
                 engine: Optional[TraversalEngine] = None,
                 faults: Optional[FaultPlan] = None,
                 retry: Optional[RetryPolicy] = None, **kw):
    """Routed upsert. Returns ``(ShardedTree', report, rounds)`` —
    ``rounds`` is the max split rounds any shard needed. New keys land in
    their owner shard only (range partition preserved); a per-shard
    capacity overflow raises exactly as the unsharded op does —
    ``rebalance`` is the recovery for skew-driven overflow."""
    qb, ql, owner = _owner_masks(st, qb, ql)
    vals = jnp.asarray(vals)
    rounds_max = 0

    def run_one(t, mask, dev):
        nonlocal rounds_max
        t2, rep, rounds = B.insert_batch(t, _put(qb, dev), _put(ql, dev),
                                         _put(vals, dev), engine=engine,
                                         mask=mask, **kw)
        rounds_max = max(rounds_max, rounds)
        return t2, rep
    shards, outcomes, failed, skipped, dropped = _routed_mutation(
        st, owner, "insert", run_one, faults, retry)
    return (st.replace(shards=shards),
            _combine(outcomes, owner, failed, skipped, dropped),
            rounds_max)


def _combine(outcomes, owner, failed=None, skipped=0,
             dropped=()) -> ShardOpReport:
    found = np.zeros(owner.shape, dtype=bool)
    splits = 0
    error = False
    conflicts = 0
    for i, (sel, rep) in enumerate(outcomes):
        found[sel] = np.asarray(rep.found)[sel]
        splits += int(rep.splits)
        error = error or bool(rep.error)
        if i == 0:
            # per-shard ops dedupe the FULL batch before the mask ANDs in,
            # so any one report already carries the global conflict count
            conflicts = int(rep.conflicts)
    if failed is None:
        failed = np.zeros(owner.shape, dtype=bool)
    return ShardOpReport(found=found, conflicts=np.int32(conflicts),
                         splits=np.int32(splits), error=np.bool_(error),
                         owner=owner, shards_hit=len(outcomes),
                         failed=failed,
                         degraded=np.zeros(owner.shape, dtype=bool),
                         shards_skipped=skipped,
                         shards_dropped=tuple(dropped))


# --------------------------------------------------------------------------
# cross-shard range scan
# --------------------------------------------------------------------------

def range_scan(st: ShardedTree, qb, ql, max_items: int = 64,
               engine: Optional[TraversalEngine] = None,
               faults: Optional[FaultPlan] = None,
               retry: Optional[RetryPolicy] = None):
    """Cross-shard range scan with spill-to-next-shard continuation.

    Returns ``(gkid int64 [B, max_items], val [B, max_items], emitted [B],
    rearranged [B], failed bool [B])`` — ascending per lane, starting at
    the first key >= the query; ``gkid`` is the global key id
    (``ShardedTree.key_rows`` resolves it), EMPTY past ``emitted``.
    Values, emitted counts, and the resolved key bytes are bit-identical
    to the unsharded §6 scan; ``rearranged`` sums the dirty leaves visited
    across shards (leaf chunking differs per partition, so it is *not*
    parity-comparable).

    Each per-shard scan goes through the engine's §6 scan path (fused
    kernel or jnp chain walk) and keeps its lazy-rearrangement ordering
    guarantee; the merge is pure concatenation because the partition is by
    key range.

    Degradation: a lane whose next needed shard is unreachable is marked
    ``failed`` and stops there — its emissions so far are a correct
    ascending *prefix* of the full result, and the flag says it may be
    truncated. A result is never silently shortened: ``failed[i] is
    False`` guarantees lane ``i`` is complete. Failed lanes take no items
    from later shards (a contiguity gap would corrupt the ascending
    merge); snapshots are not substituted here for the same reason.
    """
    if max_items < 1:
        raise ValueError(
            f"range_scan: max_items must be >= 1, got {max_items} — each "
            f"lane emits up to max_items (key, value) pairs")
    qb, ql, owner = _owner_masks(st, qb, ql)
    Bn = qb.shape[0]
    L = st.config.key_width
    stride = st.kid_stride
    vdt = np.asarray(jnp.zeros((), st.config.val_dtype)).dtype
    out_kid = np.full((Bn, max_items), EMPTY, dtype=np.int64)
    out_val = np.zeros((Bn, max_items), dtype=vdt)
    emitted = np.zeros((Bn,), dtype=np.int32)
    rearranged = np.zeros((Bn,), dtype=np.int32)
    failed = np.zeros((Bn,), dtype=bool)
    park_b = np.full((L,), 0xFF, dtype=np.uint8)   # parked lanes descend to
    park_l = np.int32(L)                           # the last leaf, emit ~0
    qb_np = np.asarray(qb)
    ql_np = np.asarray(ql)
    cols = np.arange(max_items, dtype=np.int32)[None, :]
    rows = np.broadcast_to(np.arange(Bn, dtype=np.int32)[:, None],
                           (Bn, max_items))

    for s, t in enumerate(st.shards):
        active = (owner <= s) & (emitted < max_items) & ~failed
        if not active.any():
            # stop only when NO lane can still gain: lanes owned by later
            # shards haven't started yet (owners are clustered, e.g. {0, 3})
            if not (owner > s).any():
                break
            continue
        sqb = np.where(active[:, None], qb_np, park_b[None, :])
        sql = np.where(active, ql_np, park_l).astype(np.int32)
        dev = st.devices[s]
        res = _dispatch(
            st, s, "range_scan",
            lambda: B.range_scan(t, _put(jnp.asarray(sqb), dev),
                                 _put(jnp.asarray(sql), dev),
                                 max_items=max_items, engine=engine),
            faults, retry)
        if res is None:
            failed |= active      # partial prefix, flagged — never silent
            obs.counter("shard.failed_lanes", op="range_scan").inc(
                int(active.sum()))
            obs.event("shard.failed", op="range_scan", shard=s,
                      lanes=int(active.sum()))
            continue
        kid_s, val_s, em_s, re_s = res
        kid_s = np.asarray(kid_s)
        val_s = np.asarray(val_s)
        em_s = np.asarray(em_s)
        take = np.where(active,
                        np.minimum(em_s, max_items - emitted), 0)
        ok = cols < take[:, None]          # emitted slots only: kid_s >= 0
        dst = emitted[:, None] + cols
        out_kid[rows[ok], dst[ok]] = kid_s[ok].astype(np.int64) + s * stride
        out_val[rows[ok], dst[ok]] = val_s[ok]
        emitted += take.astype(np.int32)
        rearranged += np.where(active, np.asarray(re_s), 0).astype(np.int32)
    return out_kid, out_val, emitted, rearranged, failed


# --------------------------------------------------------------------------
# rebalance — the skew-recovery barrier
# --------------------------------------------------------------------------

def rebalance(st: ShardedTree, device: bool = True,
              faults: Optional[FaultPlan] = None
              ) -> Tuple[ShardedTree, RebalanceReport]:
    """Re-partition the live key set evenly across shards.

    Built on the rebuild primitive (DESIGN.md §5/§7):
    ``core.batch_ops.gather_live_sorted`` snapshots each shard — sorted,
    tombstones dropped, pool compacted, the exact front half of
    ``rebuild`` — and because shards are range-partitioned, concatenating
    the snapshots in shard order IS the globally sorted live set. That set
    re-enters :func:`repro.shard.build.sharded_build` with the *same*
    shared ``TreeConfig`` (no recompiles) and the same mesh placement:
    step 1's sort re-distributed, steps 2–3 (the §5 device build) per
    shard, and a fresh router from the new balanced boundaries.

    Same barrier semantics as ``rebuild``: key ids (global ones included)
    are not stable across it, versions reset, values carry over. With
    ``n_shards == 1`` this degenerates to exactly ``rebuild``.

    This is also the **recovery barrier** (DESIGN.md §8): the snapshots
    are gathered from the authoritative per-shard arrays — which survive a
    dispatch outage intact — so every committed op is carried over, and
    the fresh ShardedTree starts with all-healthy ``health`` and new
    barrier ``snapshots``, re-admitting any shard that was marked down.
    Run it inside ``core.lifecycle.TreeVersionManager.publish`` (or use
    ``manager.rebalance()``) to make it abortable: a fault below — the
    sites ``lifecycle.rebalance.gather``/``.build`` fire per step — then
    leaves the old partition serving.
    """
    counts_before = tuple(int(t.n_keys_live) for t in st.shards)
    with obs.span("shard.rebalance", n_shards=st.n_shards):
        kbs, kls, vvs = [], [], []
        reclaimed = 0
        for s, t in enumerate(st.shards):
            if faults is not None:
                faults.fire("lifecycle.rebalance.gather", shard=s)
            kb, kl, _, vv, n_live = B.gather_live_sorted(t)
            n = int(n_live)
            reclaimed += int(t.arrays.key_count) - n
            kbs.append(np.asarray(kb)[:n])
            kls.append(np.asarray(kl)[:n])
            vvs.append(np.asarray(vv)[:n])
        ks = K.KeySet(np.concatenate(kbs, axis=0),
                      np.concatenate(kls, axis=0))
        vals = np.concatenate(vvs, axis=0)
        if faults is not None:
            faults.fire("lifecycle.rebalance.build")
        # the concatenation is already globally sorted (invariant above) —
        # presorted skips re-running step 1's lexsort at every barrier
        st2 = sharded_build(ks, vals, st.n_shards, cfg=st.config,
                            device=device, mesh=st.mesh, presorted=True)
    rep = RebalanceReport(
        n_live=ks.n, reclaimed=reclaimed, counts_before=counts_before,
        counts_after=tuple(int(t.n_keys_live) for t in st2.shards))
    obs.event("rebalance", n_live=rep.n_live, reclaimed=rep.reclaimed)
    return st2, rep
