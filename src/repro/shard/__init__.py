"""Sharded-tree subsystem (DESIGN.md §7): one FBTree per range shard over a
``jax.sharding.Mesh``, a replicated split-key router, shard-local dispatch
of every batch op through the traversal engine, cross-shard range scans,
and ``rebalance`` as the skew-recovery barrier.

Stable public surface — import from here, not from the submodules:

    from repro.shard import ShardedTree, sharded_build, lookup_batch, ...
"""
from .build import sharded_build
from .mesh import make_shard_mesh, shard_devices
from .ops import (DEFAULT_RETRY, RebalanceReport, ShardOpReport,
                  insert_batch, lookup_batch, range_scan, rebalance,
                  remove_batch, update_batch)
from .router import ShardRouter, make_router, route
from .tree import ShardedTree, ShardHealth

__all__ = [
    "ShardedTree", "ShardHealth", "sharded_build",
    "ShardRouter", "make_router", "route",
    "make_shard_mesh", "shard_devices",
    "lookup_batch", "update_batch", "insert_batch", "remove_batch",
    "range_scan", "rebalance",
    "ShardOpReport", "RebalanceReport", "DEFAULT_RETRY",
]
