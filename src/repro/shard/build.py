"""Sharded bulk build (DESIGN.md §7): the §5 pipeline with step 1 — the
global sort — going distributed, steps 2–3 unchanged per shard.

``fbtree.sharded_partition`` sorts the key set once and splits it into
balanced contiguous runs; each run then feeds an ordinary per-shard
``bulk_build`` (host reference or the jit device pipeline — the §5 parity
contract holds shard by shard), and the runs' minimum keys become the
replicated router. Every shard shares one ``TreeConfig`` planned for
``per_shard_max_keys`` (default: the full ``max_keys``, so any single
shard can absorb the whole key set before a ``rebalance`` — skew-safe, at
S× pool memory; pass a tighter value when memory matters).
"""
from __future__ import annotations

from typing import Any, Optional

import numpy as np

from repro.core import keys as K
from repro.core.fbtree import TreeConfig, bulk_build, sharded_partition

from .mesh import make_shard_mesh, place_shard, shard_devices
from .router import make_router
from .tree import ShardedTree

__all__ = ["sharded_build"]


def sharded_build(ks: K.KeySet, vals, n_shards: int,
                  max_keys: Optional[int] = None,
                  per_shard_max_keys: Optional[int] = None,
                  device: bool = False, mesh: Any = "auto",
                  cfg: Optional[TreeConfig] = None, presorted: bool = False,
                  **plan_kw) -> ShardedTree:
    """Bulk-load a :class:`ShardedTree` from (possibly unsorted) unique keys.

    Arguments mirror ``bulk_build`` + ``TreeConfig.plan``:

    * ``n_shards``            number of range partitions (``ks.n >=
      n_shards``).
    * ``max_keys``            global capacity plan (default ``ks.n``).
    * ``per_shard_max_keys``  per-shard capacity (default ``max_keys``:
      every shard planned for the whole set — skew-safe).
    * ``device``              per-shard device build (§5 jit pipeline)
      instead of the host reference; both are bit-identical per shard.
    * ``mesh``                ``"auto"`` builds a 1-D shard mesh over the
      local devices; ``None`` skips placement (arrays stay on the default
      device); or pass an explicit ``jax.sharding.Mesh``. Shards are
      committed to mesh devices round-robin.
    * ``cfg``                 explicit shared per-shard ``TreeConfig``
      (overrides the plan; all shards must use one config so ops compile
      once).
    * ``presorted``           the keys are already in the global sort
      order — skip step 1's sort (rebalance's concatenated snapshots).
    * ``plan_kw``             forwarded to ``TreeConfig.plan`` (ns, fs,
      leaf_fill, val_dtype, stacked, ...).
    """
    if n_shards < 1:
        raise ValueError(
            f"sharded_build: n_shards must be >= 1, got {n_shards}")
    if ks.n < n_shards:
        raise ValueError(
            f"sharded_build: need at least one key per shard to define "
            f"the range partition (n={ks.n} < n_shards={n_shards}) — "
            f"lower n_shards or seed per-shard sentinel keys the way "
            f"serving.PrefixCache does")
    nv = np.asarray(vals).shape[0]
    if nv != ks.n:
        raise ValueError(
            f"sharded_build: {nv} values for {ks.n} keys — one value per "
            f"key")
    if cfg is not None and cfg.key_width != ks.width:
        raise ValueError(
            f"sharded_build: TreeConfig.key_width={cfg.key_width} but the "
            f"key set is packed to width {ks.width} — plan the config "
            f"with key_width={ks.width} (routing and descent compare "
            f"fixed-width padded rows)")
    if cfg is None:
        if max_keys is None:
            max_keys = ks.n
        if per_shard_max_keys is None:
            per_shard_max_keys = max_keys
        cfg = TreeConfig.plan(max_keys=int(per_shard_max_keys),
                              key_width=ks.width, **plan_kw)
    parts, split_keys = sharded_partition(ks, vals, n_shards,
                                          presorted=presorted)
    if mesh == "auto":
        mesh = make_shard_mesh(n_shards)
    devices = shard_devices(mesh, n_shards)
    shards = []
    for (pks, pvals), dev in zip(parts, devices):
        t = bulk_build(cfg, pks, np.asarray(pvals), device=device)
        shards.append(place_shard(t, dev))
    return ShardedTree(shards=tuple(shards), router=make_router(split_keys),
                       devices=devices, mesh=mesh)
