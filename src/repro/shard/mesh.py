"""Shard meshes and device placement (DESIGN.md §7).

Single-host multi-device first: a 1-D ``jax.sharding.Mesh`` over the
``"shard"`` axis, shards assigned round-robin when there are more shards
than devices (every shard still gets a concrete device, so a 1-device CPU
run degrades to colocated shards with identical semantics). Tests and CI
force a multi-device CPU with ``XLA_FLAGS=--xla_force_host_platform_
device_count=N`` — set *before* the first jax import, which is why the
benchmark wires it through the environment rather than here.

Importing this module never touches jax device state (same rule as
``launch/mesh.py``); devices are only enumerated when a mesh is built.
"""
from __future__ import annotations

from typing import Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh

__all__ = ["make_shard_mesh", "shard_devices", "place_shard"]


def make_shard_mesh(n_shards: int, devices: Optional[Sequence] = None
                    ) -> Mesh:
    """1-D ``("shard",)`` mesh over ``min(n_shards, len(devices))`` devices
    (default: all local devices). With one device this is the degenerate
    single-device mesh every test environment supports."""
    if devices is None:
        devices = jax.devices()
    n = max(1, min(int(n_shards), len(devices)))
    return Mesh(np.asarray(devices[:n]), ("shard",))


def shard_devices(mesh: Optional[Mesh], n_shards: int) -> Tuple:
    """Round-robin device per shard (``None`` per shard when no mesh —
    arrays stay wherever jax put them)."""
    if mesh is None:
        return (None,) * n_shards
    devs = list(mesh.devices.flat)
    return tuple(devs[s % len(devs)] for s in range(n_shards))


def place_shard(tree, device):
    """Commit one shard's arrays to its device (no-op without a device)."""
    if device is None:
        return tree
    return jax.device_put(tree, device)
