"""Replicated split-key router (DESIGN.md §7).

The router is the sharded tree's only global state: the per-shard minimum
keys from the build's balanced partition (``fbtree.sharded_partition``),
replicated to every dispatch site. Shard ``s`` owns the key range
``[split[s], split[s+1])``; shard 0 additionally owns everything below
``split[0]`` (so the router never rejects a key, mirroring how child 0 of
an inner node absorbs keys below ``anchors[0]``).

Routing uses the same packed-word compares the tree itself descends with
(``core.keys.pack_words_j``): split keys are packed once into
order-preserving int32 words at construction, and :func:`route` resolves a
query batch with one ``[B, S, W]`` vectorized 3-way compare — first
differing word decides, equal padded words fall back to the length
tie-break, exactly ``core.keys.compare_padded``'s order at a quarter of
the columns.

``ShardRouter`` is a NamedTuple of arrays (a pytree), so it rides through
``jax.jit`` as a traced input; the shard count is its shape.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import keys as K

__all__ = ["ShardRouter", "make_router", "route"]


class ShardRouter(NamedTuple):
    """Replicated routing table: one row per shard, ascending.

    ``split_bytes[s]`` / ``split_lens[s]`` are shard ``s``'s minimum key
    (kept in byte form so ``rebalance`` and repr/debugging can read them);
    ``split_words`` is the packed order-preserving int32 form
    :func:`route` compares against.
    """
    split_bytes: jnp.ndarray   # uint8 [S, L]
    split_lens: jnp.ndarray    # int32 [S]
    split_words: jnp.ndarray   # int32 [S, W] — pack_words_j(split_bytes)

    @property
    def n_shards(self) -> int:
        return int(self.split_bytes.shape[0])


def make_router(split_keys) -> ShardRouter:
    """Build a router from ``fbtree.sharded_partition``'s ``split_keys``
    (a sequence of ``(bytes_row, len)`` per shard, ascending)."""
    sb = np.stack([np.asarray(b, dtype=np.uint8) for b, _ in split_keys])
    sl = np.asarray([int(l) for _, l in split_keys], dtype=np.int32)
    return ShardRouter(split_bytes=jnp.asarray(sb),
                       split_lens=jnp.asarray(sl),
                       split_words=jnp.asarray(K.pack_words_j(sb)))


@jax.jit
def route(router: ShardRouter, qb, ql) -> jnp.ndarray:
    """Owning shard id per query: ``int32 [B]``.

    ``owner[i]`` is the largest ``s`` with ``q_i >= split[s]`` (0 when the
    query sorts below every split key — shard 0's open left end). The
    compare is lexicographic over packed words with the length tie-break,
    identical in order to the byte compare the leaves use.
    """
    qw = K.pack_words_j(jnp.asarray(qb))               # [B, W]
    ql = jnp.asarray(ql).astype(jnp.int32)
    sw, sl = router.split_words, router.split_lens
    gt = (qw[:, None, :] > sw[None, :, :])             # [B, S, W]
    lt = (qw[:, None, :] < sw[None, :, :])
    d = gt.astype(jnp.int32) - lt.astype(jnp.int32)
    nz = d != 0
    idx = jnp.argmax(nz, axis=-1)                      # first differing word
    first = jnp.take_along_axis(d, idx[..., None], axis=-1)[..., 0]
    cmp = jnp.where(nz.any(-1), first,
                    jnp.sign(ql[:, None] - sl[None, :]))
    ge = (cmp >= 0).astype(jnp.int32)                  # splits ascending
    return jnp.maximum(ge.sum(-1) - 1, 0).astype(jnp.int32)
