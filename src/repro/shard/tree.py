"""ShardedTree container (DESIGN.md §7): one FBTree per shard + a
replicated split-key router.

The inner levels of every shard are ordinary FBTree levels over that
shard's own leaf/key arrays — "replicated inner levels, sharded leaf/key
pool" falls out of the range partition: each shard's (small) tree is fully
resident wherever its queries are routed, while the global key pool and
leaf chain exist only as the disjoint union of the per-shard arrays. All
shards share ONE ``TreeConfig``, so every batched op compiles once and
runs against any shard (and the dispatch loop reuses the same executable
across devices).

Invariants (`tests/test_shard_tree.py` pins them):

* **Range partition.** Shard ``s`` holds exactly the live keys in
  ``[split[s], split[s+1])`` (shard 0's range is open below). Routed
  inserts preserve this; only ``rebalance`` moves the boundaries.
* **Global order = shard order.** Concatenating the shards' sorted live
  key sets in shard order is the globally sorted live key set — the
  property the cross-shard range scan's merge relies on.
* **Parity.** Every batch op on a ShardedTree is bit-identical (values,
  found-ness, emitted counts, resolved key bytes) to the same op on one
  unsharded tree over the same keys, for any shard count.

Key ids are pool-local per shard; cross-shard APIs (``range_scan``) return
**global key ids** ``gkid = shard * (key_cap + 1) + kid`` (int64, EMPTY
stays -1) which :meth:`ShardedTree.key_rows` resolves back to bytes.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import numpy as np

from repro.core.fbtree import EMPTY, FBTree, TreeConfig

from .router import ShardRouter

__all__ = ["ShardedTree"]


@dataclasses.dataclass
class ShardedTree:
    """Host-side container: per-shard trees, router, optional placement.

    Not a jax pytree — dispatch is a host loop launching one jitted op per
    shard (async on that shard's device); only the per-shard FBTrees and
    the router live on device.
    """
    shards: Tuple[FBTree, ...]
    router: ShardRouter
    devices: Tuple = ()            # per-shard jax device (None = unplaced)
    mesh: object = None            # jax.sharding.Mesh | None (documentation
    #                                + bench introspection; ops only use
    #                                `devices`)

    def __post_init__(self):
        if not self.devices:
            self.devices = (None,) * len(self.shards)
        assert len(self.devices) == len(self.shards)
        assert self.router.n_shards == len(self.shards)

    # ------------------------------------------------------------- shape
    @property
    def n_shards(self) -> int:
        return len(self.shards)

    @property
    def config(self) -> TreeConfig:
        return self.shards[0].config

    @property
    def kid_stride(self) -> int:
        """Rows per shard key pool — the global-key-id stride."""
        return self.config.key_cap + 1

    @property
    def n_keys_live(self) -> int:
        return sum(t.n_keys_live for t in self.shards)

    def replace(self, **kw) -> "ShardedTree":
        return dataclasses.replace(self, **kw)

    # ------------------------------------------------------- global kids
    def split_gkid(self, gkid: np.ndarray):
        """Decode global key ids -> (shard [.., ], local kid [..,]);
        EMPTY lanes map to (0, EMPTY)."""
        g = np.asarray(gkid, dtype=np.int64)
        ok = g >= 0
        shard = np.where(ok, g // self.kid_stride, 0).astype(np.int32)
        kid = np.where(ok, g % self.kid_stride, EMPTY).astype(np.int32)
        return shard, kid

    def key_rows(self, gkid: np.ndarray):
        """Resolve global key ids to ``(key_bytes uint8[.., L], lens
        int32[..])``; EMPTY ids resolve to zero rows."""
        shard, kid = self.split_gkid(gkid)
        L = self.config.key_width
        out_b = np.zeros(shard.shape + (L,), dtype=np.uint8)
        out_l = np.zeros(shard.shape, dtype=np.int32)
        for s, t in enumerate(self.shards):
            sel = (shard == s) & (kid >= 0)
            if not sel.any():
                continue
            kb = np.asarray(t.arrays.key_bytes)
            kl = np.asarray(t.arrays.key_lens)
            out_b[sel] = kb[kid[sel]]
            out_l[sel] = kl[kid[sel]]
        return out_b, out_l

    # ----------------------------------------------------- op delegation
    # thin method facade over repro.shard.ops (imported lazily to keep the
    # module graph acyclic); the functional API is the primary surface
    def lookup(self, qb, ql, engine=None):
        from . import ops
        return ops.lookup_batch(self, qb, ql, engine=engine)

    def update(self, qb, ql, vals, engine=None):
        from . import ops
        return ops.update_batch(self, qb, ql, vals, engine=engine)

    def insert(self, qb, ql, vals, engine=None, **kw):
        from . import ops
        return ops.insert_batch(self, qb, ql, vals, engine=engine, **kw)

    def remove(self, qb, ql, engine=None):
        from . import ops
        return ops.remove_batch(self, qb, ql, engine=engine)

    def range_scan(self, qb, ql, max_items: int = 64, engine=None):
        from . import ops
        return ops.range_scan(self, qb, ql, max_items=max_items,
                              engine=engine)

    def rebalance(self, device: bool = True):
        from . import ops
        return ops.rebalance(self, device=device)
