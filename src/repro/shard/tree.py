"""ShardedTree container (DESIGN.md §7): one FBTree per shard + a
replicated split-key router.

The inner levels of every shard are ordinary FBTree levels over that
shard's own leaf/key arrays — "replicated inner levels, sharded leaf/key
pool" falls out of the range partition: each shard's (small) tree is fully
resident wherever its queries are routed, while the global key pool and
leaf chain exist only as the disjoint union of the per-shard arrays. All
shards share ONE ``TreeConfig``, so every batched op compiles once and
runs against any shard (and the dispatch loop reuses the same executable
across devices).

Invariants (`tests/test_shard_tree.py` pins them):

* **Range partition.** Shard ``s`` holds exactly the live keys in
  ``[split[s], split[s+1])`` (shard 0's range is open below). Routed
  inserts preserve this; only ``rebalance`` moves the boundaries.
* **Global order = shard order.** Concatenating the shards' sorted live
  key sets in shard order is the globally sorted live key set — the
  property the cross-shard range scan's merge relies on.
* **Parity.** Every batch op on a ShardedTree is bit-identical (values,
  found-ness, emitted counts, resolved key bytes) to the same op on one
  unsharded tree over the same keys, for any shard count.

Key ids are pool-local per shard; cross-shard APIs (``range_scan``) return
**global key ids** ``gkid = shard * (key_cap + 1) + kid`` (int64, EMPTY
stays -1) which :meth:`ShardedTree.key_rows` resolves back to bytes.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import numpy as np

from repro.core.fbtree import EMPTY, FBTree, TreeConfig

from .router import ShardRouter

__all__ = ["ShardedTree", "ShardHealth"]


class ShardHealth:
    """Mutable host-side health registry, shared across the functional
    ``replace`` chain (DESIGN.md §8).

    A shard is marked down when a routed dispatch exhausts its retries
    (``shard.ops._dispatch``); while down, ops skip its launches outright
    and report its lanes ``failed`` (mutations) or serve them ``degraded``
    from the last-barrier snapshot (lookups). The shard's *arrays* are
    always intact — only dispatch reachability is modeled — so
    ``rebalance()`` (which builds a fresh ShardedTree with fresh health)
    is the re-admission path and no committed op is ever lost.
    """

    def __init__(self, n_shards: int):
        self.ok = np.ones(int(n_shards), dtype=bool)
        self.reasons = [""] * int(n_shards)

    def is_ok(self, s: int) -> bool:
        return bool(self.ok[s])

    def mark_down(self, s: int, reason: str = ""):
        self.ok[s] = False
        self.reasons[s] = reason

    def mark_up(self, s: int):
        self.ok[s] = True
        self.reasons[s] = ""

    def reset(self):
        self.ok[:] = True
        self.reasons = [""] * self.ok.shape[0]

    @property
    def n_unhealthy(self) -> int:
        return int((~self.ok).sum())

    def __repr__(self):
        down = [f"{s}:{r or 'down'}" for s, r in enumerate(self.reasons)
                if not self.ok[s]]
        return (f"ShardHealth({self.ok.size} shards, "
                f"{'all ok' if not down else 'down ' + ', '.join(down)})")


@dataclasses.dataclass
class ShardedTree:
    """Host-side container: per-shard trees, router, optional placement.

    Not a jax pytree — dispatch is a host loop launching one jitted op per
    shard (async on that shard's device); only the per-shard FBTrees and
    the router live on device.

    ``health`` is deliberately a *shared mutable* object: routed ops
    return a functionally-updated ShardedTree (``replace``), and a shard
    marked down mid-batch must stay down in every tree object derived
    from that lineage until a ``rebalance`` barrier re-admits it.
    ``snapshots`` are the per-shard trees as of the last barrier
    (build/rebalance) — the read-only fallback degraded lookups serve
    from; in-place commits advance ``shards`` but never ``snapshots``.
    """
    shards: Tuple[FBTree, ...]
    router: ShardRouter
    devices: Tuple = ()            # per-shard jax device (None = unplaced)
    mesh: object = None            # jax.sharding.Mesh | None (documentation
    #                                + bench introspection; ops only use
    #                                `devices`)
    health: ShardHealth = None     # shared across replace() lineage
    snapshots: Tuple[FBTree, ...] = ()   # last-barrier per-shard trees

    def __post_init__(self):
        if not self.devices:
            self.devices = (None,) * len(self.shards)
        if self.health is None:
            self.health = ShardHealth(len(self.shards))
        if not self.snapshots:
            self.snapshots = self.shards
        if len(self.devices) != len(self.shards):
            raise ValueError(
                f"ShardedTree: {len(self.devices)} devices for "
                f"{len(self.shards)} shards — one device slot per shard "
                f"(None for unplaced)")
        if self.router.n_shards != len(self.shards):
            raise ValueError(
                f"ShardedTree: router has {self.router.n_shards} split "
                f"keys for {len(self.shards)} shards — rebuild the router "
                f"with make_router over one min key per shard")
        if self.health.ok.size != len(self.shards):
            raise ValueError(
                f"ShardedTree: health tracks {self.health.ok.size} shards "
                f"but the tree has {len(self.shards)}")

    # ------------------------------------------------------------- shape
    @property
    def n_shards(self) -> int:
        return len(self.shards)

    @property
    def config(self) -> TreeConfig:
        return self.shards[0].config

    @property
    def kid_stride(self) -> int:
        """Rows per shard key pool — the global-key-id stride."""
        return self.config.key_cap + 1

    @property
    def n_keys_live(self) -> int:
        return sum(t.n_keys_live for t in self.shards)

    def replace(self, **kw) -> "ShardedTree":
        return dataclasses.replace(self, **kw)

    # ------------------------------------------------------- global kids
    def split_gkid(self, gkid: np.ndarray):
        """Decode global key ids -> (shard [.., ], local kid [..,]);
        EMPTY lanes map to (0, EMPTY)."""
        g = np.asarray(gkid, dtype=np.int64)
        ok = g >= 0
        shard = np.where(ok, g // self.kid_stride, 0).astype(np.int32)
        kid = np.where(ok, g % self.kid_stride, EMPTY).astype(np.int32)
        return shard, kid

    def key_rows(self, gkid: np.ndarray):
        """Resolve global key ids to ``(key_bytes uint8[.., L], lens
        int32[..])``; EMPTY ids resolve to zero rows."""
        shard, kid = self.split_gkid(gkid)
        L = self.config.key_width
        out_b = np.zeros(shard.shape + (L,), dtype=np.uint8)
        out_l = np.zeros(shard.shape, dtype=np.int32)
        for s, t in enumerate(self.shards):
            sel = (shard == s) & (kid >= 0)
            if not sel.any():
                continue
            kb = np.asarray(t.arrays.key_bytes)
            kl = np.asarray(t.arrays.key_lens)
            out_b[sel] = kb[kid[sel]]
            out_l[sel] = kl[kid[sel]]
        return out_b, out_l

    # ----------------------------------------------------- op delegation
    # thin method facade over repro.shard.ops (imported lazily to keep the
    # module graph acyclic); the functional API is the primary surface
    def lookup(self, qb, ql, engine=None, **kw):
        from . import ops
        return ops.lookup_batch(self, qb, ql, engine=engine, **kw)

    def update(self, qb, ql, vals, engine=None, **kw):
        from . import ops
        return ops.update_batch(self, qb, ql, vals, engine=engine, **kw)

    def insert(self, qb, ql, vals, engine=None, **kw):
        from . import ops
        return ops.insert_batch(self, qb, ql, vals, engine=engine, **kw)

    def remove(self, qb, ql, engine=None, **kw):
        from . import ops
        return ops.remove_batch(self, qb, ql, engine=engine, **kw)

    def range_scan(self, qb, ql, max_items: int = 64, engine=None, **kw):
        from . import ops
        return ops.range_scan(self, qb, ql, max_items=max_items,
                              engine=engine, **kw)

    def rebalance(self, device: bool = True, **kw):
        from . import ops
        return ops.rebalance(self, device=device, **kw)
