"""Compatibility helpers for optional third-party dependencies."""
