"""Minimal stand-in for the ``hypothesis`` API surface our tests use.

The test suite property-tests tree ops with hypothesis; some environments
(hermetic containers) cannot pip-install it. Rather than skipping those
suites, :func:`install` registers this module as ``hypothesis`` /
``hypothesis.strategies`` in ``sys.modules`` so the tests run against
deterministic pseudo-random sampling: each example draws from a
``random.Random`` seeded by (test name, example index) — reproducible
across runs, no shrinking, no database.

Only the strategies the repo's tests need are provided: integers, booleans,
binary, sampled_from, lists, sets, tuples, data. CI installs the real
package (see requirements-dev.txt); this fallback never shadows it —
``install`` is a no-op when the genuine library is importable.
"""
from __future__ import annotations

import enum
import functools
import random
import sys
import types
from typing import Any, Callable

DEFAULT_MAX_EXAMPLES = 25
_MAX_REJECTS = 2000


class HealthCheck(enum.Enum):
    data_too_large = 1
    filter_too_much = 2
    too_slow = 3
    function_scoped_fixture = 4
    differing_executors = 5


class SearchStrategy:
    def __init__(self, draw_fn: Callable[[random.Random], Any]):
        self._draw_fn = draw_fn

    def example_from(self, rnd: random.Random) -> Any:
        return self._draw_fn(rnd)


def integers(min_value: int, max_value: int) -> SearchStrategy:
    # random.Random.randint is arbitrary precision — safe for ±2**63 bounds
    return SearchStrategy(lambda rnd: rnd.randint(min_value, max_value))


def booleans() -> SearchStrategy:
    return SearchStrategy(lambda rnd: rnd.random() < 0.5)


def binary(min_size: int = 0, max_size: int = 16) -> SearchStrategy:
    def draw(rnd):
        n = rnd.randint(min_size, max_size)
        return bytes(rnd.getrandbits(8) for _ in range(n))
    return SearchStrategy(draw)


def sampled_from(seq) -> SearchStrategy:
    seq = list(seq)
    return SearchStrategy(lambda rnd: seq[rnd.randrange(len(seq))])


def lists(elements: SearchStrategy, min_size: int = 0, max_size: int = 16,
          unique: bool = False) -> SearchStrategy:
    def draw(rnd):
        n = rnd.randint(min_size, max_size)
        if not unique:
            return [elements.example_from(rnd) for _ in range(n)]
        out, seen = [], set()
        for _ in range(_MAX_REJECTS):
            if len(out) >= n:
                break
            v = elements.example_from(rnd)
            if v not in seen:
                seen.add(v)
                out.append(v)
        if len(out) < min_size:
            raise RuntimeError("hypothesis fallback: could not draw "
                               f"{min_size} unique elements")
        return out
    return SearchStrategy(draw)


def sets(elements: SearchStrategy, min_size: int = 0,
         max_size: int = 16) -> SearchStrategy:
    base = lists(elements, min_size=min_size, max_size=max_size, unique=True)
    return SearchStrategy(lambda rnd: set(base.example_from(rnd)))


def tuples(*strategies: SearchStrategy) -> SearchStrategy:
    return SearchStrategy(
        lambda rnd: tuple(s.example_from(rnd) for s in strategies))


class DataObject:
    def __init__(self, rnd: random.Random):
        self._rnd = rnd

    def draw(self, strategy: SearchStrategy, label: str = None) -> Any:
        return strategy.example_from(self._rnd)


def data() -> SearchStrategy:
    return SearchStrategy(lambda rnd: DataObject(rnd))


def given(*gargs: SearchStrategy, **gkwargs: SearchStrategy):
    def decorate(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            n = getattr(wrapper, "_fallback_max_examples",
                        DEFAULT_MAX_EXAMPLES)
            for i in range(n):
                rnd = random.Random(f"{fn.__module__}.{fn.__name__}#{i}")
                drawn = [s.example_from(rnd) for s in gargs]
                kw = {k: s.example_from(rnd) for k, s in gkwargs.items()}
                fn(*args, *drawn, **kwargs, **kw)
        # drop __wrapped__ so pytest sees (*args, **kwargs) and does not
        # mistake the strategy-filled parameters for fixtures
        del wrapper.__wrapped__
        wrapper._fallback_max_examples = DEFAULT_MAX_EXAMPLES
        return wrapper
    return decorate


def settings(deadline=None, max_examples: int = DEFAULT_MAX_EXAMPLES,
             suppress_health_check=(), **_ignored):
    def decorate(fn):
        fn._fallback_max_examples = max_examples
        return fn
    return decorate


def install() -> None:
    """Register this module as ``hypothesis`` unless the real one exists."""
    try:
        import hypothesis  # noqa: F401
        return
    except ImportError:
        pass
    mod = types.ModuleType("hypothesis")
    mod.given = given
    mod.settings = settings
    mod.HealthCheck = HealthCheck
    mod.__version__ = "0.0-fallback"

    st_mod = types.ModuleType("hypothesis.strategies")
    for name in ("integers", "booleans", "binary", "sampled_from", "lists",
                 "sets", "tuples", "data"):
        setattr(st_mod, name, globals()[name])
    st_mod.SearchStrategy = SearchStrategy

    mod.strategies = st_mod
    sys.modules["hypothesis"] = mod
    sys.modules["hypothesis.strategies"] = st_mod
