from .config import ModelConfig  # noqa: F401
from . import attention, blocks, lm, layers, mamba, mla, moe  # noqa: F401
