"""Model configuration for the 10 assigned architectures.

One dataclass covers every family (dense / moe / ssm / hybrid / encdec / vlm);
family-specific fields are ignored elsewhere. All dims come from the
assignment block (public literature); `param_count()` feeds the roofline's
MODEL_FLOPS = 6·N·D (N_active for MoE).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    vocab: int
    # ---- attention ----
    n_heads: int = 0
    n_kv_heads: int = 0
    head_dim: int = 0                # 0 -> d_model // n_heads
    qkv_bias: bool = False
    qk_norm: bool = False
    attention: str = "gqa"           # gqa | mla | none
    rope_theta: float = 10_000.0
    pos: str = "rope"                # rope | learned | none
    window: int = 0                  # 0 = full attention; >0 sliding window
    # ---- MLA (deepseek) ----
    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128
    # ---- mlp ----
    d_ff: int = 0
    mlp: str = "swiglu"              # swiglu | geglu | relu2 | gelu
    norm: str = "rms"                # rms | ln
    norm_eps: float = 1e-5
    # ---- MoE ----
    n_experts: int = 0               # routed experts (0 = dense)
    top_k: int = 1
    n_shared_experts: int = 0
    d_ff_expert: int = 0
    n_dense_layers: int = 0          # leading dense layers (deepseek: 3)
    d_ff_dense: int = 0              # their ff width
    router: str = "softmax"          # softmax | sigmoid (deepseek)
    capacity_factor: float = 1.25
    moe_impl: str = "scatter"        # scatter (optimized) | gshard (baseline)
    # ---- MTP (deepseek) ----
    mtp: bool = False
    mtp_weight: float = 0.1
    # ---- SSM ----
    ssm_state: int = 0
    ssm_version: int = 1             # 1 = mamba1 (falcon), 2 = mamba2 (zamba)
    d_conv: int = 4
    expand: int = 2
    ssm_headdim: int = 64            # mamba2 head dim
    dt_rank: int = 0                 # mamba1; 0 -> d_model // 16
    ssm_scan: str = "assoc"          # assoc | cumsum (§Perf lever)
    # ---- hybrid (zamba2) ----
    shared_attn_period: int = 0      # every k-th block is the shared attn block
    shared_lora_rank: int = 0        # per-occurrence LoRA on the shared block
    # ---- enc-dec (whisper) ----
    n_enc_layers: int = 0
    enc_seq: int = 0                 # encoder positions (stub frame embeddings)
    frontend_dim: int = 0            # stub frontend embedding width
    # ---- vlm (paligemma) ----
    n_patches: int = 0               # image prefix length
    # ---- misc ----
    tie_embeddings: bool = True
    param_dtype: str = "bfloat16"
    remat: str = "none"              # none | full | dots  (activation ckpt)

    # ----------------------------------------------------------------- helpers
    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // max(self.n_heads, 1))

    @property
    def dtype(self):
        return jnp.dtype(self.param_dtype)

    @property
    def d_inner(self) -> int:
        return self.expand * self.d_model

    @property
    def dtr(self) -> int:
        return self.dt_rank or max(1, self.d_model // 16)

    @property
    def n_ssm_heads(self) -> int:
        return self.d_inner // self.ssm_headdim

    def moe_layer_ids(self) -> Tuple[int, ...]:
        if self.n_experts == 0:
            return ()
        return tuple(range(self.n_dense_layers, self.n_layers))

    def hybrid_pattern(self) -> Tuple[str, ...]:
        """Block type per position for hybrid archs ('m'=mamba, 'a'=shared attn)."""
        if self.family != "hybrid":
            return ()
        p = []
        for i in range(self.n_layers):
            if self.shared_attn_period and (i + 1) % self.shared_attn_period == 0:
                p.append("a")
            else:
                p.append("m")
        return tuple(p)

    # -------------------------------------------------------------- param count
    def _attn_params(self) -> int:
        d, H, Hk, hd = self.d_model, self.n_heads, self.n_kv_heads, self.hd
        if self.attention == "mla":
            qr, kr = self.q_lora_rank, self.kv_lora_rank
            nope, rope, vh = self.qk_nope_head_dim, self.qk_rope_head_dim, self.v_head_dim
            return (d * qr + qr * H * (nope + rope)           # q down/up
                    + d * (kr + rope)                          # kv down + shared k_rope
                    + kr * H * (nope + vh)                     # kv up
                    + H * vh * d)                              # o
        n = d * H * hd + 2 * d * Hk * hd + H * hd * d
        if self.qkv_bias:
            n += H * hd + 2 * Hk * hd
        return n

    def _mlp_params(self, ff: int) -> int:
        d = self.d_model
        if self.mlp in ("swiglu", "geglu"):
            return 3 * d * ff
        return 2 * d * ff

    def _moe_layer_params(self) -> Tuple[int, int]:
        """(total, active) params of one MoE layer's FFN part."""
        d, fe = self.d_model, self.d_ff_expert
        per = self._mlp_params(fe) // (self.d_model * 0 + 1)
        per = 3 * d * fe if self.mlp in ("swiglu", "geglu") else 2 * d * fe
        router = d * self.n_experts
        shared = self.n_shared_experts * per
        total = self.n_experts * per + shared + router
        active = self.top_k * per + shared + router
        return total, active

    def _mamba_params(self) -> int:
        d, di, ds = self.d_model, self.d_inner, self.ssm_state
        if self.ssm_version == 1:
            return (d * 2 * di + di * self.d_conv            # in_proj + conv
                    + di * (self.dtr + 2 * ds)               # x_proj
                    + self.dtr * di + di                     # dt_proj
                    + di * ds + di                           # A, D
                    + di * d)                                # out_proj
        nh = self.n_ssm_heads
        return (d * (2 * di + 2 * ds + nh)                   # in_proj(z,x,B,C,dt)
                + (di + 2 * ds) * self.d_conv
                + nh + nh + di                               # A, D, norm
                + di * d)

    def param_count(self) -> Tuple[int, int]:
        """(total, active) parameter counts (embeddings included once)."""
        d = self.d_model
        emb = self.vocab * d
        head = 0 if self.tie_embeddings else self.vocab * d
        norms = 2 * d * self.n_layers + d
        total = active = emb + head + norms

        if self.family in ("dense", "vlm"):
            per = self._attn_params() + self._mlp_params(self.d_ff)
            total += per * self.n_layers
            active = total
        elif self.family == "moe":
            attn = self._attn_params()
            mt, ma = self._moe_layer_params()
            n_moe = self.n_layers - self.n_dense_layers
            dense = self._mlp_params(self.d_ff_dense or self.d_ff)
            total += (attn + dense) * self.n_dense_layers + (attn + mt) * n_moe
            active += (attn + dense) * self.n_dense_layers + (attn + ma) * n_moe
            if self.mtp:
                mt2, ma2 = self._moe_layer_params()
                total += attn + mt2 + 2 * d * d
                active += attn + ma2 + 2 * d * d
        elif self.family == "ssm":
            total += self._mamba_params() * self.n_layers
            active = total
        elif self.family == "hybrid":
            pat = self.hybrid_pattern()
            nm = pat.count("m")
            na = pat.count("a")
            shared = self._attn_params() + self._mlp_params(self.d_ff)
            lora = na * self.shared_lora_rank * 2 * d * 4 if self.shared_lora_rank else 0
            total += self._mamba_params() * nm + shared + lora
            active = total
        elif self.family == "encdec":
            per = self._attn_params() + self._mlp_params(self.d_ff)
            enc = per * self.n_enc_layers
            dec = (2 * self._attn_params() + self._mlp_params(self.d_ff)) * self.n_layers
            pos = 2 * self.enc_seq * d + self.frontend_dim * d  # learned pos + proj
            total += enc + dec + pos
            active = total
        if self.family == "vlm":
            total += self.frontend_dim * d  # projector
            active = total
        return int(total), int(active)
