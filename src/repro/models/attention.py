"""GQA attention: training forward, prefill, and one-token decode w/ KV cache.

Supports the assigned-arch knobs: GQA group sizes (kv=1 MQA .. kv=H MHA), QKV
bias (qwen2.5), qk-norm (qwen3), sliding window, prefix-LM bidirectional
masks (paligemma), cross attention (whisper), RoPE or learned positions.

Masks are *specs*, not tensors: long sequences run a flash-style
online-softmax over (q-tile × kv-tile) pairs with tile masks built from
iotas — the [S,T] mask and the [.., S, T] logits never materialize in HBM
(a 32k prefill would otherwise need a 1 GiB mask and TB-scale logits). The
inner tile body is ``jax.checkpoint``-ed so backward recomputes tile
probabilities flash-style instead of stashing them.

Decode sharding note: when ``n_kv_heads`` doesn't divide the
model axis, the KV cache shards its *sequence* dim instead; the plain einsum
decode below lets XLA turn that into flash-decoding style partial-softmax
collectives automatically.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import Params, dense_init, norm_params, rms_norm, rope_apply, rope_freqs

NEG = -1e30
FLASH_THRESH = 2048 * 2048       # S*T above this -> tiled path
Q_CHUNK = 1024
KV_CHUNK = 1024


class MaskSpec(NamedTuple):
    kind: str = "causal"             # causal | full
    window: int = 0                  # 0 = unlimited
    prefix_len: int = 0              # bidirectional prefix (PaliGemma)

    def tile(self, qi, kj):
        """Boolean tile mask from absolute indices qi [qc], kj [kc]."""
        if self.kind == "full":
            m = jnp.ones((qi.shape[0], kj.shape[0]), bool)
        else:
            m = kj[None, :] <= qi[:, None]
            if self.window:
                m &= kj[None, :] > (qi[:, None] - self.window)
            if self.prefix_len:
                m |= kj[None, :] < self.prefix_len
        return m


CAUSAL = MaskSpec("causal")
FULL = MaskSpec("full")


def proj_out(flat, wo):
    """[B,S,H*hv] x wo[H,hv,d] -> [B,S,d]."""
    B, S = flat.shape[:2]
    H, hv, d = wo.shape
    return jnp.einsum("bsnh,nhd->bsd", flat.reshape(B, S, H, hv), wo)


class KVCache(NamedTuple):
    k: jnp.ndarray  # [B, S_max, n_kv, hd]
    v: jnp.ndarray  # [B, S_max, n_kv, hd]


def attn_params(key, cfg: ModelConfig, dtype=None) -> Params:
    dtype = dtype or cfg.dtype
    d, H, Hk, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    ks = jax.random.split(key, 6)
    p = {"wq": dense_init(ks[0], d, H, hd, dtype=dtype),
         "wk": dense_init(ks[1], d, Hk, hd, dtype=dtype),
         "wv": dense_init(ks[2], d, Hk, hd, dtype=dtype),
         # [H, hd, d] so either heads or head_dim can shard
         "wo": (jax.random.truncated_normal(ks[3], -2.0, 2.0, (H, hd, d),
                                            jnp.float32)
                * ((H * hd) ** -0.5)).astype(dtype)}
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((H, hd), dtype)
        p["bk"] = jnp.zeros((Hk, hd), dtype)
        p["bv"] = jnp.zeros((Hk, hd), dtype)
    if cfg.qk_norm:
        p["qnorm"] = norm_params(ks[4], hd, "rms", dtype)
        p["knorm"] = norm_params(ks[5], hd, "rms", dtype)
    return p


def _qkv(p: Params, cfg: ModelConfig, x, positions):
    q = jnp.einsum("bsd,dnh->bsnh", x, p["wq"])
    k = jnp.einsum("bsd,dnh->bsnh", x, p["wk"])
    v = jnp.einsum("bsd,dnh->bsnh", x, p["wv"])
    if cfg.qkv_bias:
        q = q + p["bq"]
        k = k + p["bk"]
        v = v + p["bv"]
    if cfg.qk_norm:
        q = rms_norm(q, p["qnorm"]["w"], cfg.norm_eps)
        k = rms_norm(k, p["knorm"]["w"], cfg.norm_eps)
    if cfg.pos == "rope" and positions is not None:
        sin, cos = rope_freqs(positions, cfg.hd, cfg.rope_theta)
        q = rope_apply(q, sin, cos)
        k = rope_apply(k, sin, cos)
    return q, k, v


# ------------------------------------------------------------------ sdpa
def sdpa(q, k, v, mask: Optional[MaskSpec], n_rep: int,
         scale: Optional[float] = None):
    """q [B,S,H,hd], k/v [B,T,Hk,hd] -> [B,S,H*hd]. mask=None means full.

    Dispatch: small sequences use the exact single-softmax einsum; long
    sequences use the scan-tiled online softmax; on a real TPU backend the
    Pallas fused kernel takes the long path instead (tiles stay in VMEM —
    the scan path's tile logits round-trip HBM, which §Roofline shows
    dominating 32k-prefill memory terms).
    """
    B, S, H, hd = q.shape
    T = k.shape[1]
    mask = mask or FULL
    if S * T > FLASH_THRESH:
        if jax.default_backend() == "tpu":
            from repro.kernels.flash_attention.ops import flash_sdpa
            return flash_sdpa(q, k, v, mask, n_rep, scale or hd ** -0.5)
        return _sdpa_flash(q, k, v, mask, n_rep, scale)
    return _sdpa_small(q, k, v, mask, n_rep, scale)


def _sdpa_small(q, k, v, mask: MaskSpec, n_rep: int, scale=None):
    B, S, H, hd = q.shape
    T, Hk = k.shape[1], k.shape[2]
    hv = v.shape[-1]
    scale = scale or hd ** -0.5
    qg = q.reshape(B, S, Hk, n_rep, hd)
    logits = jnp.einsum("bskrh,btkh->bkrst", qg, k,
                        preferred_element_type=jnp.float32) * scale
    m = mask.tile(jnp.arange(S), jnp.arange(T))
    logits = jnp.where(m[None, None, None], logits, NEG)
    w = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkrst,btkh->bskrh", w.astype(v.dtype), v)
    return out.reshape(B, S, H * hv)


def _sdpa_flash(q, k, v, mask: MaskSpec, n_rep: int, scale=None,
                q_chunk: int = Q_CHUNK, kv_chunk: int = KV_CHUNK):
    """Online-softmax tiling; [S,T] logits never materialize."""
    B, S, H, hd = q.shape
    T, Hk = k.shape[1], k.shape[2]
    hv = v.shape[-1]
    scale = scale or hd ** -0.5
    qc = min(q_chunk, S)
    kc = min(kv_chunk, T)
    nq, nk = -(-S // qc), -(-T // kc)
    Sp, Tp = nq * qc, nk * kc
    qg = jnp.pad(q, [(0, 0), (0, Sp - S), (0, 0), (0, 0)])
    kg = jnp.pad(k, [(0, 0), (0, Tp - T), (0, 0), (0, 0)])
    vg = jnp.pad(v, [(0, 0), (0, Tp - T), (0, 0), (0, 0)])
    qg = qg.reshape(B, nq, qc, Hk, n_rep, hd).transpose(1, 0, 3, 4, 2, 5)
    kg = kg.reshape(B, nk, kc, Hk, hd).transpose(1, 0, 3, 2, 4)
    vg = vg.reshape(B, nk, kc, Hk, hv).transpose(1, 0, 3, 2, 4)
    # qg [nq, B, Hk, rep, qc, hd]; kg/vg [nk, B, Hk, kc, hd]

    def q_tile(_, qi_blk):
        qt, iq = qi_blk                      # [B,Hk,rep,qc,hd], scalar
        qidx = iq * qc + jnp.arange(qc)

        @jax.checkpoint
        def kv_tile(carry, kv_blk):
            m_run, l_run, acc = carry
            kt, vt, jk = kv_blk
            kidx = jk * kc + jnp.arange(kc)
            s = jnp.einsum("bkrqh,bkch->bkrqc", qt, kt,
                           preferred_element_type=jnp.float32) * scale
            tm = mask.tile(qidx, kidx) & (kidx < T)[None, :]
            s = jnp.where(tm[None, None, None], s, NEG)
            m_new = jnp.maximum(m_run, s.max(-1))
            p = jnp.where(tm[None, None, None],
                          jnp.exp(s - m_new[..., None]), 0.0)
            corr = jnp.exp(m_run - m_new)
            l_new = l_run * corr + p.sum(-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bkrqc,bkch->bkrqh", p.astype(vt.dtype), vt
            ).astype(jnp.float32)
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, Hk, n_rep, qc), NEG, jnp.float32)
        l0 = jnp.zeros((B, Hk, n_rep, qc), jnp.float32)
        a0 = jnp.zeros((B, Hk, n_rep, qc, hv), jnp.float32)
        (m_f, l_f, acc), _ = jax.lax.scan(
            kv_tile, (m0, l0, a0),
            (kg, vg, jnp.arange(nk, dtype=jnp.int32)))
        out = acc / jnp.maximum(l_f, 1e-20)[..., None]
        return None, out.astype(q.dtype)

    _, tiles = jax.lax.scan(q_tile, None,
                            (qg, jnp.arange(nq, dtype=jnp.int32)))
    # tiles [nq, B, Hk, rep, qc, hv] -> [B, S, H*hv]
    out = tiles.transpose(1, 0, 4, 2, 3, 5).reshape(B, Sp, Hk * n_rep * hv)
    return out[:, :S]


def causal_mask(S: int, T: int, offset: int = 0, window: int = 0,
                prefix_len=None):
    """Materialized bool mask (small/decode paths only)."""
    qi = jnp.arange(S)[:, None] + offset
    kj = jnp.arange(T)[None, :]
    m = kj <= qi
    if window:
        m &= kj > qi - window
    if prefix_len is not None:
        m |= kj < prefix_len
    return m


def attn_forward(p: Params, cfg: ModelConfig, x, positions,
                 mask: Optional[MaskSpec]) -> jnp.ndarray:
    """Training/prefill attention over the full sequence."""
    q, k, v = _qkv(p, cfg, x, positions)
    out = sdpa(q, k, v, mask, cfg.n_heads // cfg.n_kv_heads)
    return proj_out(out, p["wo"])


def attn_prefill(p: Params, cfg: ModelConfig, x, positions,
                 mask: Optional[MaskSpec], cache_len: int,
                 ) -> Tuple[jnp.ndarray, KVCache]:
    """Prefill: run full attention AND return a KV cache padded to cache_len."""
    q, k, v = _qkv(p, cfg, x, positions)
    B, S = x.shape[:2]
    out = sdpa(q, k, v, mask, cfg.n_heads // cfg.n_kv_heads)
    pad = [(0, 0), (0, cache_len - S), (0, 0), (0, 0)]
    return proj_out(out, p["wo"]), KVCache(
        jnp.pad(k, pad).astype(jnp.bfloat16),
        jnp.pad(v, pad).astype(jnp.bfloat16))


def attn_decode(p: Params, cfg: ModelConfig, x, pos, cache: KVCache,
                ) -> Tuple[jnp.ndarray, KVCache]:
    """One-token decode. x [B,1,d]; pos int32 [B] absolute position."""
    q, k, v = _qkv(p, cfg, x, pos[:, None])
    B = x.shape[0]
    S_max = cache.k.shape[1]
    bidx = jnp.arange(B)
    newk = cache.k.at[bidx, pos].set(k[:, 0].astype(cache.k.dtype))
    newv = cache.v.at[bidx, pos].set(v[:, 0].astype(cache.v.dtype))
    valid = jnp.arange(S_max)[None, :] <= pos[:, None]         # [B, S_max]
    if cfg.window:
        valid &= jnp.arange(S_max)[None, :] > (pos[:, None] - cfg.window)
    Hk, n_rep = cfg.n_kv_heads, cfg.n_heads // cfg.n_kv_heads
    hd = cfg.hd
    qg = q.reshape(B, Hk, n_rep, hd)
    logits = jnp.einsum("bkrh,btkh->bkrt", qg, newk.astype(x.dtype),
                        preferred_element_type=jnp.float32) * hd ** -0.5
    logits = jnp.where(valid[:, None, None], logits, NEG)
    w = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkrt,btkh->bkrh", w.astype(x.dtype),
                     newv.astype(x.dtype)).reshape(B, 1, Hk * n_rep * hd)
    return proj_out(out, p["wo"]), KVCache(newk, newv)


# ------------------------------------------------------------------ cross attn
def cross_attn_params(key, cfg: ModelConfig, dtype=None) -> Params:
    dtype = dtype or cfg.dtype
    d, H, Hk, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    ks = jax.random.split(key, 4)
    return {"wq": dense_init(ks[0], d, H, hd, dtype=dtype),
            "wk": dense_init(ks[1], d, Hk, hd, dtype=dtype),
            "wv": dense_init(ks[2], d, Hk, hd, dtype=dtype),
            "wo": (jax.random.truncated_normal(ks[3], -2.0, 2.0, (H, hd, d),
                                               jnp.float32)
                   * ((H * hd) ** -0.5)).astype(dtype)}


def cross_attn_forward(p: Params, cfg: ModelConfig, x, enc_kv) -> jnp.ndarray:
    """x [B,S,d] queries; enc_kv = (k, v) precomputed from encoder output."""
    q = jnp.einsum("bsd,dnh->bsnh", x, p["wq"])
    k, v = enc_kv
    out = sdpa(q, k.astype(x.dtype), v.astype(x.dtype), FULL,
               cfg.n_heads // cfg.n_kv_heads)
    return proj_out(out, p["wo"])


def cross_kv(p: Params, enc_out) -> Tuple[jnp.ndarray, jnp.ndarray]:
    k = jnp.einsum("btd,dnh->btnh", enc_out, p["wk"])
    v = jnp.einsum("btd,dnh->btnh", enc_out, p["wv"])
    return k, v


def init_kv_cache(cfg: ModelConfig, B: int, S_max: int, n_layers: int,
                  dtype=jnp.bfloat16) -> KVCache:
    shape = (n_layers, B, S_max, cfg.n_kv_heads, cfg.hd)
    return KVCache(jnp.zeros(shape, dtype), jnp.zeros(shape, dtype))
