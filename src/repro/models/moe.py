"""Mixture-of-Experts layer with two dispatch implementations.

``gshard`` (baseline, faithful to the dominant JAX MoE literature): capacity-
bounded one-hot dispatch/combine einsums. Simple, but the one-hot contractions
cost 2·B·S·E·C·d MAC each — for DeepSeek dims that rivals the expert FFN
itself (visible in the roofline's MODEL_FLOPS/HLO_FLOPs ratio).

``scatter`` (optimized): slot assignment via a segmented-rank
sort (cheap int ops), token gather by index (0 FLOPs, local under SPMD since
the expert dim is a pure *output* dim of the gather), expert einsum, then a
scatter-add combine whose cross-shard reduction is the same all-reduce a
row-parallel FFN needs anyway. Expert dim is sharded over the "model" mesh
axis via constraints in blocks.py (expert parallelism).

Routers: softmax top-k with load-balance aux loss (Switch/GLaM style), or
sigmoid scoring with a learned-bias-corrected top-k (DeepSeek-V3's
aux-loss-free balancing; the bias is a buffer updated outside the gradient).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import Params, dense_init


def moe_params(key, cfg: ModelConfig, dtype=None) -> Params:
    dtype = dtype or cfg.dtype
    d, E, fe = cfg.d_model, cfg.n_experts, cfg.d_ff_expert
    ks = jax.random.split(key, 8)
    glu = cfg.mlp in ("swiglu", "geglu")
    # stacked expert weights: init scaled by fan-in of the *matmul* dims
    p = {"router": dense_init(ks[0], d, E, dtype=jnp.float32),
         "wi": (jax.random.truncated_normal(ks[1], -2, 2, (E, d, fe),
                                            jnp.float32)
                * (d ** -0.5)).astype(dtype),
         "wo": (jax.random.truncated_normal(ks[2], -2, 2, (E, fe, d),
                                            jnp.float32)
                * (fe ** -0.5)).astype(dtype)}
    if glu:
        p["wg"] = (jax.random.truncated_normal(ks[3], -2, 2, (E, d, fe),
                                               jnp.float32)
                   * (d ** -0.5)).astype(dtype)
    if cfg.router == "sigmoid":
        p["router_bias"] = jnp.zeros((E,), jnp.float32)   # buffer, not trained
    if cfg.n_shared_experts:
        fs = fe * cfg.n_shared_experts
        p["shared"] = {
            "wi": dense_init(ks[4], d, fs, dtype=dtype),
            "wo": dense_init(ks[5], fs, d, dtype=dtype)}
        if glu:
            p["shared"]["wg"] = dense_init(ks[6], d, fs, dtype=dtype)
    return p


def _route(p: Params, cfg: ModelConfig, x) -> Tuple[jnp.ndarray, jnp.ndarray,
                                                    jnp.ndarray]:
    """-> (topk_idx [B,S,k] int32, topk_w [B,S,k], aux_loss scalar)."""
    logits = (x.astype(jnp.float32) @ p["router"])        # [B,S,E]
    E, k = cfg.n_experts, cfg.top_k
    if cfg.router == "sigmoid":
        scores = jax.nn.sigmoid(logits)
        sel = scores + jax.lax.stop_gradient(p["router_bias"])
        _, idx = jax.lax.top_k(sel, k)
        w = jnp.take_along_axis(scores, idx, axis=-1)
        w = w / jnp.maximum(w.sum(-1, keepdims=True), 1e-9)
        aux = jnp.float32(0.0)                            # aux-loss-free
    else:
        probs = jax.nn.softmax(logits, axis=-1)
        _, idx = jax.lax.top_k(probs, k)
        w = jnp.take_along_axis(probs, idx, axis=-1)
        w = w / jnp.maximum(w.sum(-1, keepdims=True), 1e-9)
        # Switch aux: E * mean_e(frac_tokens_e * mean_prob_e)
        one = jax.nn.one_hot(idx[..., 0], E, dtype=jnp.float32)
        frac = one.mean(axis=(0, 1))
        mp = probs.mean(axis=(0, 1))
        aux = E * jnp.sum(frac * mp)
    return idx.astype(jnp.int32), w.astype(x.dtype), aux


def _expert_ffn(p: Params, cfg: ModelConfig, xb) -> jnp.ndarray:
    """xb [B,E,C,d] -> [B,E,C,d] through per-expert FFN."""
    if cfg.mlp in ("swiglu", "geglu"):
        act = jax.nn.silu if cfg.mlp == "swiglu" else jax.nn.gelu
        h = act(jnp.einsum("becd,edf->becf", xb, p["wg"])) * \
            jnp.einsum("becd,edf->becf", xb, p["wi"])
    else:
        h = jnp.square(jax.nn.relu(jnp.einsum("becd,edf->becf", xb, p["wi"])))
    return jnp.einsum("becf,efd->becd", h, p["wo"])


def _slot_assignment(idx, E: int, C: int):
    """Per-batch-row slotting: returns (slot_token [B,E,C] int32 in [0,S],
    slot_w_sel [B,E,C] int32 index into k, keep mask folded in via sentinel S).

    Sorted-segment ranking: flatten (S·k) routed slots, sort by expert id,
    rank within each expert run, keep ranks < C.
    """
    B, S, k = idx.shape
    e_flat = idx.reshape(B, S * k)
    t_flat = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[:, None],
                              (S, k)).reshape(S * k)
    k_flat = jnp.broadcast_to(jnp.arange(k, dtype=jnp.int32)[None, :],
                              (S, k)).reshape(S * k)

    def per_row(e_row):
        order = jnp.argsort(e_row, stable=True)
        se = jnp.take(e_row, order)
        n = se.shape[0]
        pos = jnp.arange(n, dtype=jnp.int32)
        is_head = jnp.concatenate([jnp.ones((1,), bool), se[1:] != se[:-1]])
        head_pos = jax.lax.associative_scan(
            jnp.maximum, jnp.where(is_head, pos, 0))
        rank = pos - head_pos
        return order, se, rank

    order, se, rank = jax.vmap(per_row)(e_flat)
    st = jnp.take(t_flat, order)          # [B, S*k] token id per sorted slot
    sk = jnp.take(k_flat, order)          # which of the k choices
    keep = rank < C
    # scatter (expert, rank) -> token index; sentinel S = padded row
    slot_token = jnp.full((B, E, C), S, jnp.int32)
    slot_ksel = jnp.zeros((B, E, C), jnp.int32)
    bi = jnp.broadcast_to(jnp.arange(B)[:, None], se.shape)
    es = jnp.where(keep, se, E - 1)
    rs = jnp.where(keep, rank, C - 1)
    # masked scatter: dropped slots collapse onto (E-1, C-1); re-set sentinel
    slot_token = slot_token.at[bi, es, rs].set(jnp.where(keep, st, S))
    slot_ksel = slot_ksel.at[bi, es, rs].set(jnp.where(keep, sk, 0))
    # (E-1, C-1) may hold garbage from drops that raced a real assignment;
    # detect: a slot is real iff its token routed to this expert at this rank
    return slot_token, slot_ksel


def capacity(cfg: ModelConfig, S: int) -> int:
    c = int(S * cfg.top_k / max(cfg.n_experts, 1) * cfg.capacity_factor)
    return max(8, -(-c // 8) * 8)


def moe_scatter(p: Params, cfg: ModelConfig, x, shard=lambda a, kind: a):
    """Optimized dispatch. x [B,S,d] -> (y [B,S,d], aux)."""
    B, S, d = x.shape
    E, k = cfg.n_experts, cfg.top_k
    C = capacity(cfg, S)
    idx, w, aux = _route(p, cfg, x)
    slot_token, slot_ksel = _slot_assignment(idx, E, C)
    slot_token = shard(slot_token, "bec")
    # slot weight: w[b, t, ksel] where slot valid else 0
    valid = slot_token < S
    t_safe = jnp.minimum(slot_token, S - 1)
    bi = jnp.arange(B)[:, None, None]
    w_slot = jnp.where(valid, w[bi, t_safe, slot_ksel], 0).astype(x.dtype)
    # double-check slot really belongs (guards scatter-collision corner)
    e_ids = jnp.broadcast_to(jnp.arange(E, dtype=jnp.int32)[None, :, None],
                             (B, E, C))
    routed_here = (idx[bi, t_safe] == e_ids[..., None]).any(-1)
    w_slot = jnp.where(routed_here, w_slot, 0)

    xpad = jnp.concatenate([x, jnp.zeros((B, 1, d), x.dtype)], axis=1)
    xb = xpad[jnp.arange(B)[:, None, None], slot_token]   # [B,E,C,d] gather
    xb = shard(xb, "becd")
    h = _expert_ffn(p, cfg, xb)                           # [B,E,C,d]
    h = shard(h, "becd")
    h = h * w_slot[..., None]
    y = jnp.zeros((B, S + 1, d), x.dtype)
    y = y.at[jnp.arange(B)[:, None, None], slot_token].add(h)  # combine
    y = y[:, :S]
    return shard(y, "bsd"), aux


@jax.custom_vjp
def gather_dispatch(xpad, slot_token):
    """xb[b,e,c,:] = xpad[b, slot_token[b,e,c], :].

    Forward: plain gather — 0 FLOPs, local under SPMD (expert dim is a pure
    output dim). Backward: the natural VJP (scatter-add into the token dim
    with expert-sharded updates) triggers GSPMD's replicate-updates fallback
    (measured: +195 s collective on deepseek train_4k), so we supply the
    mathematically-identical one-hot einsum transpose instead — contraction
    over the sharded expert dim partitions into local partials + one
    all-reduce, the same pattern as a row-parallel matmul backward.
    """
    B = xpad.shape[0]
    return xpad[jnp.arange(B)[:, None, None], slot_token]


def _gd_fwd(xpad, slot_token):
    return gather_dispatch(xpad, slot_token), (slot_token, xpad.shape[1])


def _gd_bwd(res, g):
    slot_token, S1 = res
    onehot = (slot_token[:, None, :, :] ==
              jnp.arange(S1, dtype=jnp.int32)[None, :, None, None]
              ).astype(g.dtype)
    dx = jnp.einsum("bsec,becd->bsd", onehot, g)
    return dx, None


gather_dispatch.defvjp(_gd_fwd, _gd_bwd)


def moe_mixed(p: Params, cfg: ModelConfig, x, shard=lambda a, kind: a):
    """Optimized: gather-dispatch (0 FLOPs, local under SPMD — the expert
    dim is a pure output dim of the gather) + one-hot *combine* einsum whose
    cross-shard reduction is the row-parallel all-reduce. Halves the GShard
    one-hot overhead and never materializes the dispatch side of D."""
    B, S, d = x.shape
    E, k = cfg.n_experts, cfg.top_k
    C = capacity(cfg, S)
    idx, w, aux = _route(p, cfg, x)
    slot_token, slot_ksel = _slot_assignment(idx, E, C)
    slot_token = shard(slot_token, "bec")
    valid = slot_token < S
    t_safe = jnp.minimum(slot_token, S - 1)
    bi = jnp.arange(B)[:, None, None]
    w_slot = jnp.where(valid, w[bi, t_safe, slot_ksel], 0)
    e_ids = jnp.broadcast_to(jnp.arange(E, dtype=jnp.int32)[None, :, None],
                             (B, E, C))
    routed_here = (idx[bi, t_safe] == e_ids[..., None]).any(-1)
    w_slot = jnp.where(routed_here, w_slot, 0)

    xpad = jnp.concatenate([x, jnp.zeros((B, 1, d), x.dtype)], axis=1)
    xb = gather_dispatch(xpad, slot_token)                # gather dispatch
    xb = shard(xb, "becd")
    h = _expert_ffn(p, cfg, xb)
    h = shard(h, "becd")
    onehot_t = (slot_token[:, None, :, :] ==
                jnp.arange(S, dtype=jnp.int32)[None, :, None, None])
    D = onehot_t.astype(x.dtype) * w_slot[:, None, :, :].astype(x.dtype)
    D = shard(D, "bsec")
    y = jnp.einsum("bsec,becd->bsd", D, h)                # combine einsum
    return shard(y, "bsd"), aux


def moe_gshard(p: Params, cfg: ModelConfig, x, shard=lambda a, kind: a):
    """Baseline one-hot dispatch/combine einsums (capacity-bounded)."""
    B, S, d = x.shape
    E, k = cfg.n_experts, cfg.top_k
    C = capacity(cfg, S)
    idx, w, aux = _route(p, cfg, x)
    slot_token, slot_ksel = _slot_assignment(idx, E, C)
    valid = slot_token < S
    t_safe = jnp.minimum(slot_token, S - 1)
    bi = jnp.arange(B)[:, None, None]
    w_slot = jnp.where(valid, w[bi, t_safe, slot_ksel], 0)
    # one-hot dispatch mask D0 [B,S,E,C]; router weights apply on COMBINE
    # only (dispatching weighted inputs would square the gate through the
    # expert nonlinearity)
    onehot_t = (slot_token[:, None, :, :] ==
                jnp.arange(S, dtype=jnp.int32)[None, :, None, None])
    D0 = shard(onehot_t.astype(x.dtype), "bsec")
    Dw = shard(D0 * w_slot[:, None, :, :].astype(x.dtype), "bsec")
    xb = jnp.einsum("bsec,bsd->becd", D0, x)              # dispatch einsum
    xb = shard(xb, "becd")
    h = _expert_ffn(p, cfg, xb)
    h = shard(h, "becd")
    y = jnp.einsum("bsec,becd->bsd", Dw, h)               # combine einsum
    return shard(y, "bsd"), aux


def shared_expert(p: Params, cfg: ModelConfig, x) -> jnp.ndarray:
    if "shared" not in p:
        return jnp.zeros_like(x)
    sp = p["shared"]
    if cfg.mlp in ("swiglu", "geglu"):
        act = jax.nn.silu if cfg.mlp == "swiglu" else jax.nn.gelu
        h = act(x @ sp["wg"]) * (x @ sp["wi"])
    else:
        h = jnp.square(jax.nn.relu(x @ sp["wi"]))
    return h @ sp["wo"]


def moe_apply(p: Params, cfg: ModelConfig, x, shard=lambda a, kind: a):
    fn = {"scatter": moe_scatter, "gshard": moe_gshard,
          "mixed": moe_mixed}[cfg.moe_impl]
    y, aux = fn(p, cfg, x, shard)
    return y + shared_expert(p, cfg, x), aux
