"""Model assembly: init / forward / prefill / decode for every family.

Layer stacks are scanned (``jax.lax.scan`` over stacked params) so the HLO —
and therefore dry-run compile time on 512 virtual devices — stays small and
shape-static. ``shard`` is an injected activation-constraint hook
(parallel.sharding.shard); model code never touches the mesh directly.
"""
from __future__ import annotations

import functools
from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from . import attention as A
from . import blocks as BLK
from . import mamba as M
from . import mla as MLA
from .config import ModelConfig
from .layers import Params, apply_norm, dense_init, embed_init, norm_params

NOSHARD = lambda a, k: a


def _maybe_remat(fn, cfg: ModelConfig):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        policy = jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims
        return jax.checkpoint(fn, policy=policy)
    return jax.checkpoint(fn)


# ===================================================================== init
def init_params(cfg: ModelConfig, key) -> Params:
    keys = jax.random.split(key, 16)
    d, dtype = cfg.d_model, cfg.dtype
    p: Params = {"embed": embed_init(keys[0], cfg.vocab, d, dtype),
                 "final_norm": norm_params(keys[1], d, cfg.norm, dtype)}
    if not cfg.tie_embeddings:
        p["lm_head"] = dense_init(keys[2], d, cfg.vocab, dtype=dtype)

    if cfg.family == "hybrid":
        pat = cfg.hybrid_pattern()
        n_sh = pat.count("a")
        per = cfg.shared_attn_period
        n_grp, grp_m = n_sh, per - 1
        n_tail = cfg.n_layers - n_sh * per
        km = jax.random.split(keys[3], n_grp * grp_m).reshape(n_grp, grp_m, 2)
        p["mamba_grp"] = jax.vmap(jax.vmap(
            lambda k: BLK.block_params(k, cfg, "mamba2")))(km)
        if n_tail:
            kt = jax.random.split(keys[4], n_tail)
            p["mamba_tail"] = jax.vmap(
                lambda k: BLK.block_params(k, cfg, "mamba2"))(kt)
        p["shared"] = BLK.shared_block_params(keys[5], cfg)
        if cfg.shared_lora_rank:
            kl = jax.random.split(keys[6], n_grp)
            p["lora"] = jax.vmap(
                lambda k: BLK.shared_lora_params(k, cfg))(kl)
        return p

    if cfg.family == "encdec":
        ke = jax.random.split(keys[3], cfg.n_enc_layers)
        kd = jax.random.split(keys[4], cfg.n_layers)
        p["enc"] = {
            "proj": dense_init(keys[5], cfg.frontend_dim, d, dtype=dtype),
            "pos": dense_init(keys[6], cfg.enc_seq, d, dtype=dtype) * 0.02,
            "blocks": jax.vmap(lambda k: BLK.enc_block_params(k, cfg))(ke),
            "ln_f": norm_params(keys[7], d, cfg.norm, dtype),
        }
        p["dec_pos"] = dense_init(keys[8], cfg.enc_seq, d, dtype=dtype) * 0.02
        p["dec_blocks"] = jax.vmap(lambda k: BLK.dec_block_params(k, cfg))(kd)
        return p

    if cfg.family == "vlm":
        p["proj"] = dense_init(keys[9], cfg.frontend_dim, d, dtype=dtype)

    for i, (kind, n) in enumerate(BLK.block_kinds(cfg)):
        kk = jax.random.split(keys[10 + i], n)
        p[f"seg{i}"] = jax.vmap(
            lambda k: BLK.block_params(k, cfg, kind))(kk)

    if cfg.mtp:
        kind = BLK.block_kinds(cfg)[-1][0]
        p["mtp"] = {
            "proj": dense_init(keys[14], 2 * d, d, dtype=dtype),
            "norm_h": norm_params(keys[15], d, cfg.norm, dtype),
            "norm_e": norm_params(keys[15], d, cfg.norm, dtype),
            "block": BLK.block_params(keys[13], cfg, kind),
        }
    return p


def unembed(p: Params, cfg: ModelConfig, x, shard=NOSHARD):
    if cfg.tie_embeddings:
        logits = x @ p["embed"].T
    else:
        logits = x @ p["lm_head"]
    return shard(logits, "bsv")


# ===================================================================== train
def forward(p: Params, cfg: ModelConfig, batch: Dict, shard=NOSHARD,
            ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """-> (logits [B,S,V], aux_loss, hidden [B,S,d])."""
    if cfg.family == "encdec":
        return _forward_encdec(p, cfg, batch, shard)

    tokens = batch["tokens"]
    B, S = tokens.shape
    x = shard(p["embed"][tokens], "bsd")
    prefix_len = None
    if cfg.family == "vlm":
        xp = batch["patches"].astype(x.dtype) @ p["proj"]
        x = jnp.concatenate([shard(xp, "bsd"), x], axis=1)
        prefix_len = cfg.n_patches
        S = x.shape[1]
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    mask = A.MaskSpec("causal", cfg.window, prefix_len or 0)
    aux = jnp.float32(0.0)

    if cfg.family == "hybrid":
        x = _hybrid_forward(p, cfg, x, positions, mask, shard)
    else:
        for i, (kind, n) in enumerate(BLK.block_kinds(cfg)):
            def body(h, pl, _kind=kind):
                h2, a = BLK.block_forward(pl, cfg, _kind, h, positions, mask,
                                          shard)
                return h2, a
            body = _maybe_remat(body, cfg)
            x, auxs = jax.lax.scan(body, x, p[f"seg{i}"])
            aux = aux + auxs.sum()

    h = apply_norm(x, p["final_norm"], cfg.norm, cfg.norm_eps)
    logits = unembed(p, cfg, h, shard)
    return logits, aux, h


def _hybrid_forward(p, cfg, x, positions, mask, shard):
    def mbody(h, pl):
        h2, _ = BLK.block_forward(pl, cfg, "mamba2", h, positions, mask, shard)
        return h2, None

    lora = p.get("lora")
    n_grp = p["mamba_grp"]["ln1"]["w"].shape[0]

    def group(h, xs):
        mgrp, lg = xs
        h, _ = jax.lax.scan(_maybe_remat(mbody, cfg), h, mgrp)
        h = BLK.shared_block_forward(p["shared"],
                                     lg if lora is not None else None,
                                     cfg, h, positions, mask, shard)
        return h, None

    lg_xs = lora if lora is not None else jnp.zeros((n_grp, 0))
    x, _ = jax.lax.scan(group, x, (p["mamba_grp"], lg_xs))
    if "mamba_tail" in p:
        x, _ = jax.lax.scan(_maybe_remat(mbody, cfg), x, p["mamba_tail"])
    return x


def _forward_encdec(p, cfg: ModelConfig, batch, shard):
    enc_out = encode(p, cfg, batch["frames"], shard)
    tokens = batch["tokens"]
    B, S = tokens.shape
    x = shard(p["embed"][tokens], "bsd") + p["dec_pos"][:S]
    positions = None
    mask = A.MaskSpec("causal")

    def body(h, pl):
        g = apply_norm(h, pl["ln1"], cfg.norm, cfg.norm_eps)
        h = h + shard(A.attn_forward(pl["attn"], cfg, g, positions, mask),
                      "bsd")
        g = apply_norm(h, pl["lnx"], cfg.norm, cfg.norm_eps)
        kv = A.cross_kv(pl["cross"], enc_out)
        h = h + shard(A.cross_attn_forward(pl["cross"], cfg, g, kv), "bsd")
        g = apply_norm(h, pl["ln2"], cfg.norm, cfg.norm_eps)
        from .layers import mlp_apply
        h = h + shard(mlp_apply(pl["mlp"], g, cfg.mlp), "bsd")
        return h, None

    x, _ = jax.lax.scan(_maybe_remat(body, cfg), x, p["dec_blocks"])
    h = apply_norm(x, p["final_norm"], cfg.norm, cfg.norm_eps)
    return unembed(p, cfg, h, shard), jnp.float32(0.0), h


def encode(p, cfg: ModelConfig, frames, shard=NOSHARD):
    """Whisper encoder over stub frame embeddings [B, T, frontend_dim]."""
    e = p["enc"]
    T = frames.shape[1]
    x = shard(frames.astype(cfg.dtype) @ e["proj"], "bsd") + e["pos"][:T]

    def body(h, pl):
        g = apply_norm(h, pl["ln1"], cfg.norm, cfg.norm_eps)
        h = h + shard(A.attn_forward(pl["attn"], cfg, g, None, None), "bsd")
        g = apply_norm(h, pl["ln2"], cfg.norm, cfg.norm_eps)
        from .layers import mlp_apply
        h = h + shard(mlp_apply(pl["mlp"], g, cfg.mlp), "bsd")
        return h, None

    x, _ = jax.lax.scan(_maybe_remat(body, cfg), x, e["blocks"])
    return apply_norm(x, e["ln_f"], cfg.norm, cfg.norm_eps)


def mtp_logits(p: Params, cfg: ModelConfig, hidden, tokens, shard=NOSHARD):
    """DeepSeek multi-token-prediction head: predict token t+2 from the main
    trunk's hidden at t combined with the embedding of token t+1."""
    mtp = p["mtp"]
    B, S = tokens.shape
    nxt = jnp.concatenate([tokens[:, 1:], tokens[:, -1:]], axis=1)
    he = apply_norm(p["embed"][nxt], mtp["norm_e"], cfg.norm, cfg.norm_eps)
    hh = apply_norm(hidden, mtp["norm_h"], cfg.norm, cfg.norm_eps)
    x = jnp.concatenate([hh, he], axis=-1) @ mtp["proj"]
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    mask = A.MaskSpec("causal")
    kind = BLK.block_kinds(cfg)[-1][0]
    x, _ = BLK.block_forward(mtp["block"], cfg, kind, x, positions, mask,
                             shard)
    x = apply_norm(x, p["final_norm"], cfg.norm, cfg.norm_eps)
    return unembed(p, cfg, x, shard)


# ===================================================================== caches
def init_cache(cfg: ModelConfig, B: int, S_max: int):
    if cfg.family == "hybrid":
        per = cfg.shared_attn_period
        n_sh = cfg.n_layers // per
        grp_m = per - 1
        n_tail = cfg.n_layers - n_sh * per
        grp = M.init_mamba_state(cfg, B, n_sh * grp_m)
        grp = jax.tree_util.tree_map(
            lambda a: a.reshape((n_sh, grp_m) + a.shape[1:]), grp)
        cache = {"grp": grp,
                 "attn": A.init_kv_cache(cfg, B, S_max, n_sh)}
        if n_tail:
            cache["tail"] = M.init_mamba_state(cfg, B, n_tail)
        return cache
    if cfg.family == "ssm":
        return M.init_mamba_state(cfg, B, cfg.n_layers)
    if cfg.family == "encdec":
        return {"self": A.init_kv_cache(cfg, B, S_max, cfg.n_layers),
                "cross_k": jnp.zeros((cfg.n_layers, B, cfg.enc_seq,
                                      cfg.n_kv_heads, cfg.hd), jnp.bfloat16),
                "cross_v": jnp.zeros((cfg.n_layers, B, cfg.enc_seq,
                                      cfg.n_kv_heads, cfg.hd), jnp.bfloat16)}
    if cfg.attention == "mla":
        segs = BLK.block_kinds(cfg)
        L = cfg.n_layers
        return MLA.MLACache(
            jnp.zeros((L, B, S_max, cfg.kv_lora_rank), jnp.bfloat16),
            jnp.zeros((L, B, S_max, cfg.qk_rope_head_dim), jnp.bfloat16))
    return A.init_kv_cache(cfg, B, S_max, cfg.n_layers)


# ===================================================================== decode
def decode_step(p: Params, cfg: ModelConfig, tokens, pos, cache,
                shard=NOSHARD, enc_out=None):
    """One new token for every sequence. tokens [B] int32, pos [B] int32.
    Returns (logits [B, V], cache')."""
    B = tokens.shape[0]
    x = p["embed"][tokens][:, None, :]          # [B,1,d]

    if cfg.family == "hybrid":
        x, cache = _hybrid_decode(p, cfg, x, pos, cache)
    elif cfg.family == "encdec":
        x = x + jnp.take(p["dec_pos"], pos, axis=0)[:, None]
        def body(h, xs):
            pl, ck, cv, xk, xv = xs
            g = apply_norm(h, pl["ln1"], cfg.norm, cfg.norm_eps)
            y, newc = A.attn_decode(pl["attn"], cfg, g, pos, A.KVCache(ck, cv))
            h = h + y
            g = apply_norm(h, pl["lnx"], cfg.norm, cfg.norm_eps)
            h = h + A.cross_attn_forward(pl["cross"], cfg, g, (xk, xv))
            g = apply_norm(h, pl["ln2"], cfg.norm, cfg.norm_eps)
            from .layers import mlp_apply
            h = h + mlp_apply(pl["mlp"], g, cfg.mlp)
            return h, (newc.k, newc.v)
        x, (nk, nv) = jax.lax.scan(
            body, x, (p["dec_blocks"], cache["self"].k, cache["self"].v,
                      cache["cross_k"], cache["cross_v"]))
        cache = dict(cache, self=A.KVCache(nk, nv))
    else:
        layer_off = 0
        new_caches = []
        for i, (kind, n) in enumerate(BLK.block_kinds(cfg)):
            seg_cache = jax.tree_util.tree_map(
                lambda a: a[layer_off:layer_off + n], cache)
            def body(h, xs, _kind=kind):
                pl, c = xs
                h2, c2 = BLK.block_decode(pl, cfg, _kind, h, pos, c, shard)
                return h2, c2
            x, newc = jax.lax.scan(body, x, (p[f"seg{i}"], seg_cache))
            new_caches.append(newc)
            layer_off += n
        cache = jax.tree_util.tree_map(
            lambda *xs: jnp.concatenate(xs, axis=0), *new_caches) \
            if len(new_caches) > 1 else new_caches[0]

    h = apply_norm(x, p["final_norm"], cfg.norm, cfg.norm_eps)
    logits = unembed(p, cfg, h, shard)[:, 0]
    return logits, cache


def _hybrid_decode(p, cfg, x, pos, cache):
    def mbody(h, xs):
        pl, c = xs
        h2, c2 = BLK.block_decode(pl, cfg, "mamba2", h, pos, c)
        return h2, c2

    lora = p.get("lora")
    n_grp = cache["attn"].k.shape[0]

    def group(h, xs):
        mgrp, cgrp, ck, cv, lg = xs
        h, cgrp2 = jax.lax.scan(mbody, h, (mgrp, cgrp))
        h, ac = BLK.shared_block_decode(p["shared"],
                                        lg if lora is not None else None,
                                        cfg, h, pos, A.KVCache(ck, cv))
        return h, (cgrp2, ac.k, ac.v)

    lg_xs = lora if lora is not None else jnp.zeros((n_grp, 0))
    x, (grp2, nk, nv) = jax.lax.scan(
        group, x, (p["mamba_grp"], cache["grp"], cache["attn"].k,
                   cache["attn"].v, lg_xs))
    out = {"grp": grp2, "attn": A.KVCache(nk, nv)}
    if "tail" in cache:
        x, tail2 = jax.lax.scan(mbody, x, (p["mamba_tail"], cache["tail"]))
        out["tail"] = tail2
    return x, out


# ===================================================================== prefill
def prefill(p: Params, cfg: ModelConfig, batch: Dict, S_max: int,
            shard=NOSHARD):
    """Process a full prompt; returns (last-token logits [B,V], cache).

    Only the final position's logits are computed (serving practice — the
    full [B,S,V] tensor never materializes).
    """
    tokens = batch["tokens"]
    B, S = tokens.shape
    x = shard(p["embed"][tokens], "bsd")
    prefix_len = None
    if cfg.family == "vlm":
        xp = batch["patches"].astype(x.dtype) @ p["proj"]
        x = jnp.concatenate([shard(xp, "bsd"), x], axis=1)
        prefix_len = cfg.n_patches
        S = x.shape[1]
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    mask = A.MaskSpec("causal", cfg.window, prefix_len or 0)

    if cfg.family == "encdec":
        enc_out = encode(p, cfg, batch["frames"], shard)
        def body(h, pl):
            g = apply_norm(h, pl["ln1"], cfg.norm, cfg.norm_eps)
            y, c = A.attn_prefill(pl["attn"], cfg, g, None, mask, S_max)
            h = h + shard(y, "bsd")
            g = apply_norm(h, pl["lnx"], cfg.norm, cfg.norm_eps)
            kv = A.cross_kv(pl["cross"], enc_out)
            h = h + shard(A.cross_attn_forward(pl["cross"], cfg, g, kv), "bsd")
            g = apply_norm(h, pl["ln2"], cfg.norm, cfg.norm_eps)
            from .layers import mlp_apply
            h = h + shard(mlp_apply(pl["mlp"], g, cfg.mlp), "bsd")
            return h, (c, kv)
        x0 = x + p["dec_pos"][:S]
        x, (c, kv) = jax.lax.scan(body, x0, p["dec_blocks"])
        cache = {"self": c,
                 "cross_k": kv[0].astype(jnp.bfloat16),
                 "cross_v": kv[1].astype(jnp.bfloat16)}
    elif cfg.family == "hybrid":
        x, cache = _hybrid_prefill(p, cfg, x, positions, mask, S_max, shard)
    elif cfg.family == "ssm":
        def body(h, pl):
            g = apply_norm(h, pl["ln1"], cfg.norm, cfg.norm_eps)
            fwd = M.mamba1_forward if cfg.ssm_version == 1 else M.mamba2_forward
            y, st = fwd(pl["mixer"], cfg, g)
            return h + shard(y, "bsd"), st
        x, cache = jax.lax.scan(body, x, p["seg0"])
    else:
        layer_off = 0
        caches = []
        for i, (kind, n) in enumerate(BLK.block_kinds(cfg)):
            def body(h, pl, _kind=kind):
                return BLK.block_prefill(pl, cfg, _kind, h, positions, mask,
                                         S_max, shard)
            x, c = jax.lax.scan(body, x, p[f"seg{i}"])
            caches.append(c)
        cache = jax.tree_util.tree_map(
            lambda *xs: jnp.concatenate(xs, axis=0), *caches) \
            if len(caches) > 1 else caches[0]

    h = apply_norm(x[:, -1:], p["final_norm"], cfg.norm, cfg.norm_eps)
    logits = unembed(p, cfg, h, shard)[:, 0]
    return logits, cache


def _hybrid_prefill(p, cfg, x, positions, mask, S_max, shard):
    def mbody(h, pl):
        g = apply_norm(h, pl["ln1"], cfg.norm, cfg.norm_eps)
        y, st = M.mamba2_forward(pl["mixer"], cfg, g)
        return h + shard(y, "bsd"), st

    lora = p.get("lora")
    n_grp = p["mamba_grp"]["ln1"]["w"].shape[0]

    def group(h, xs):
        mgrp, lg = xs
        h, sts = jax.lax.scan(mbody, h, mgrp)
        g = apply_norm(h, p["shared"]["ln1"], cfg.norm, cfg.norm_eps)
        y, kv = A.attn_prefill(p["shared"]["attn"], cfg, g, positions, mask,
                               S_max)
        if lora is not None:
            dq = (g @ lg["qa"]) @ lg["qb"]
            y = y + A.proj_out(dq, p["shared"]["attn"]["wo"])
        h = h + shard(y, "bsd")
        g = apply_norm(h, p["shared"]["ln2"], cfg.norm, cfg.norm_eps)
        from .layers import mlp_apply
        h = h + shard(mlp_apply(p["shared"]["mlp"], g, cfg.mlp), "bsd")
        return h, (sts, kv.k, kv.v)

    lg_xs = lora if lora is not None else jnp.zeros((n_grp, 0))
    x, (grp, nk, nv) = jax.lax.scan(group, x, (p["mamba_grp"], lg_xs))
    cache = {"grp": grp, "attn": A.KVCache(nk, nv)}
    if "mamba_tail" in p:
        x, tail = jax.lax.scan(mbody, x, p["mamba_tail"])
        cache["tail"] = tail
    return x, cache
