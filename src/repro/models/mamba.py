"""Mamba-1 (falcon-mamba) selective scan and Mamba-2 (zamba2) SSD, plus O(1)
decode state steps.

Training-time recurrences are parallelized TPU-natively:
  * mamba1: chunked associative scan — ``lax.scan`` over chunks (small HLO)
    carrying the SSM state, ``associative_scan`` inside each chunk (log-depth,
    VPU-friendly); the [B,Q,d_inner,d_state] discretized tensors exist one
    chunk at a time, bounding live memory.
  * mamba2: the SSD block decomposition — intra-chunk attention-like
    [Q,Q]-per-head matmuls (MXU work) + inter-chunk state recurrence via
    associative scan. Scalar-per-head decay makes this exact.

Decode: single-token state update, O(d_inner·d_state) — the reason the
``long_500k`` shape runs for SSM/hybrid archs only.
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import Params, dense_init, rms_norm


class MambaState(NamedTuple):
    conv: jnp.ndarray   # [B, conv_dim, d_conv]  rolling conv window
    ssm: jnp.ndarray    # m1: [B, d_inner, d_state]; m2: [B, nh, hp, d_state]


# ====================================================================== mamba1
def mamba1_params(key, cfg: ModelConfig, dtype=None) -> Params:
    dtype = dtype or cfg.dtype
    d, di, ds, dtr = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.dtr
    ks = jax.random.split(key, 6)
    A = jnp.broadcast_to(jnp.arange(1, ds + 1, dtype=jnp.float32), (di, ds))
    return {
        "in_proj": dense_init(ks[0], d, 2 * di, dtype=dtype),
        "conv_w": dense_init(ks[1], cfg.d_conv, di, dtype=dtype) * 0.5,
        "conv_b": jnp.zeros((di,), dtype),
        "x_proj": dense_init(ks[2], di, dtr + 2 * ds, dtype=dtype),
        "dt_w": dense_init(ks[3], dtr, di, dtype=dtype),
        "dt_b": jnp.full((di,), -4.6, jnp.float32),      # softplus^-1(0.01)
        "A_log": jnp.log(A),
        "D": jnp.ones((di,), jnp.float32),
        "out_proj": dense_init(ks[4], di, d, dtype=dtype),
    }


def _causal_conv(x, w, b, state=None):
    """Depthwise causal conv. x [B,S,C], w [K,C]. Returns (y, new_state) where
    state [B,C,K] holds the last K inputs (for decode continuation)."""
    B, S, C = x.shape
    K = w.shape[0]
    if state is None:
        xp = jnp.pad(x, [(0, 0), (K - 1, 0), (0, 0)])
    else:
        xp = jnp.concatenate([jnp.swapaxes(state, 1, 2)[:, -(K - 1):], x],
                             axis=1)
    y = sum(xp[:, i:i + S] * w[i] for i in range(K)) + b
    new_state = jnp.swapaxes(xp[:, -K:], 1, 2) if S >= 1 else state
    return y, new_state


def _assoc_seg(dA, dBx):
    """h_t = dA_t * h_{t-1} + dBx_t over axis 1 via associative scan."""
    def comb(l, r):
        return (r[0] * l[0], r[0] * l[1] + r[1])
    a, b = jax.lax.associative_scan(comb, (dA, dBx), axis=1)
    return a, b


def mamba1_forward(p: Params, cfg: ModelConfig, x, state: MambaState = None,
                   chunk: int = 256) -> Tuple[jnp.ndarray, MambaState]:
    """x [B,S,d] -> (y [B,S,d], final_state)."""
    B, S, d = x.shape
    di, ds, dtr = cfg.d_inner, cfg.ssm_state, cfg.dtr
    xz = x @ p["in_proj"]
    xm, z = xz[..., :di], xz[..., di:]
    conv_state = None if state is None else state.conv
    xm, conv_state = _causal_conv(xm, p["conv_w"], p["conv_b"], conv_state)
    xm = jax.nn.silu(xm)
    dbl = xm @ p["x_proj"]
    dt = jax.nn.softplus(dbl[..., :dtr] @ p["dt_w"]
                         + p["dt_b"]).astype(jnp.float32)       # [B,S,di]
    Bp = dbl[..., dtr:dtr + ds].astype(jnp.float32)             # [B,S,ds]
    Cp = dbl[..., dtr + ds:].astype(jnp.float32)
    A = -jnp.exp(p["A_log"])                                    # [di,ds]
    xf = xm.astype(jnp.float32)

    Q = min(chunk, S)
    nc = -(-S // Q)
    pad = nc * Q - S
    def padS(a):
        return jnp.pad(a, [(0, 0), (0, pad)] + [(0, 0)] * (a.ndim - 2))
    dt_c = padS(dt).reshape(B, nc, Q, di)
    B_c = padS(Bp).reshape(B, nc, Q, ds)
    C_c = padS(Cp).reshape(B, nc, Q, ds)
    x_c = padS(xf).reshape(B, nc, Q, di)

    h0 = (jnp.zeros((B, di, ds), jnp.float32) if state is None
          else state.ssm.astype(jnp.float32))

    def chunk_step(h, inp):
        dtq, bq, cq, xq = inp                                   # [B,Q,·]
        dBx = (dtq * xq)[..., None] * bq[:, :, None, :]         # [B,Q,di,ds]
        if cfg.ssm_scan == "cumsum":
            # log-space prefix form: h_t = e^{L_t}(h0 + Σ_{τ≤t} e^{-L_τ}u_τ)
            # one cumsum instead of associative_scan's log-depth pad/slice
            # ladder (§Perf C-cell); exponents clipped at ±60 — only terms
            # already decayed below e^-60 lose precision.
            L = jnp.cumsum(dtq[..., None] * A, axis=1)          # [B,Q,di,ds]
            w = jnp.exp(jnp.clip(-L, None, 60.0))
            acc = jnp.cumsum(w * dBx, axis=1)
            hs = jnp.exp(jnp.clip(L, -60.0, None)) * (h[:, None] + acc)
        else:
            dA = jnp.exp(dtq[..., None] * A)                    # [B,Q,di,ds]
            accA, acc = _assoc_seg(dA, dBx)
            hs = accA * h[:, None] + acc                        # [B,Q,di,ds]
        y = jnp.einsum("bqds,bqs->bqd", hs, cq)
        return hs[:, -1], y

    hT, ys = jax.lax.scan(
        chunk_step, h0,
        (dt_c.swapaxes(0, 1), B_c.swapaxes(0, 1),
         C_c.swapaxes(0, 1), x_c.swapaxes(0, 1)))
    y = ys.swapaxes(0, 1).reshape(B, nc * Q, di)[:, :S]
    y = y + xf * p["D"]
    y = (y.astype(x.dtype)) * jax.nn.silu(z)
    out = y @ p["out_proj"]
    if conv_state is None:
        conv_state = jnp.zeros((B, di, cfg.d_conv), x.dtype)
    return out, MambaState(conv_state.astype(x.dtype), hT.astype(jnp.float32))


def mamba1_decode(p: Params, cfg: ModelConfig, x, state: MambaState,
                  ) -> Tuple[jnp.ndarray, MambaState]:
    """x [B,1,d]; O(1) recurrence step."""
    di, ds, dtr = cfg.d_inner, cfg.ssm_state, cfg.dtr
    B = x.shape[0]
    xz = x[:, 0] @ p["in_proj"]
    xm, z = xz[..., :di], xz[..., di:]
    conv = jnp.concatenate([state.conv[:, :, 1:], xm[:, :, None]], axis=-1)
    xm = jnp.einsum("bck,kc->bc", conv, p["conv_w"]) + p["conv_b"]
    xm = jax.nn.silu(xm)
    dbl = xm @ p["x_proj"]
    dt = jax.nn.softplus(dbl[..., :dtr] @ p["dt_w"] + p["dt_b"]
                         ).astype(jnp.float32)
    Bp = dbl[..., dtr:dtr + ds].astype(jnp.float32)
    Cp = dbl[..., dtr + ds:].astype(jnp.float32)
    A = -jnp.exp(p["A_log"])
    dA = jnp.exp(dt[..., None] * A)                             # [B,di,ds]
    h = dA * state.ssm + (dt * xm.astype(jnp.float32))[..., None] \
        * Bp[:, None, :]
    y = jnp.einsum("bds,bs->bd", h, Cp) + xm.astype(jnp.float32) * p["D"]
    y = y.astype(x.dtype) * jax.nn.silu(z)
    return (y @ p["out_proj"])[:, None], MambaState(conv, h)


# ====================================================================== mamba2
def mamba2_params(key, cfg: ModelConfig, dtype=None) -> Params:
    dtype = dtype or cfg.dtype
    d, di, ds = cfg.d_model, cfg.d_inner, cfg.ssm_state
    nh = cfg.n_ssm_heads
    conv_dim = di + 2 * ds
    ks = jax.random.split(key, 4)
    return {
        "in_proj": dense_init(ks[0], d, 2 * di + 2 * ds + nh, dtype=dtype),
        "conv_w": dense_init(ks[1], cfg.d_conv, conv_dim, dtype=dtype) * 0.5,
        "conv_b": jnp.zeros((conv_dim,), dtype),
        "A_log": jnp.zeros((nh,), jnp.float32),
        "dt_b": jnp.full((nh,), -4.6, jnp.float32),
        "D": jnp.ones((nh,), jnp.float32),
        "norm_w": jnp.zeros((di,), dtype),
        "out_proj": dense_init(ks[2], di, d, dtype=dtype),
    }


def mamba2_forward(p: Params, cfg: ModelConfig, x, state: MambaState = None,
                   chunk: int = 128) -> Tuple[jnp.ndarray, MambaState]:
    """SSD block decomposition. x [B,S,d]."""
    B, S, d = x.shape
    di, ds = cfg.d_inner, cfg.ssm_state
    nh, hp = cfg.n_ssm_heads, cfg.ssm_headdim
    zxbcdt = x @ p["in_proj"]
    z = zxbcdt[..., :di]
    xbc = zxbcdt[..., di:di + di + 2 * ds]
    dt = jax.nn.softplus(zxbcdt[..., -nh:].astype(jnp.float32)
                         + p["dt_b"])                            # [B,S,nh]
    conv_state = None if state is None else state.conv
    xbc, conv_state = _causal_conv(xbc, p["conv_w"], p["conv_b"], conv_state)
    xbc = jax.nn.silu(xbc)
    xm = xbc[..., :di].reshape(B, S, nh, hp)
    Bp = xbc[..., di:di + ds].astype(jnp.float32)                # [B,S,ds]
    Cp = xbc[..., di + ds:].astype(jnp.float32)
    A = -jnp.exp(p["A_log"])                                     # [nh]
    dA = dt * A                                                  # [B,S,nh] (log decay)

    Q = min(chunk, S)
    nc = -(-S // Q)
    pad = nc * Q - S
    def padS(a):
        return jnp.pad(a, [(0, 0), (0, pad)] + [(0, 0)] * (a.ndim - 2))
    dA_c = padS(dA).reshape(B, nc, Q, nh)
    dt_c = padS(dt).reshape(B, nc, Q, nh)
    x_c = padS(xm.astype(jnp.float32)).reshape(B, nc, Q, nh, hp)
    B_c = padS(Bp).reshape(B, nc, Q, ds)
    C_c = padS(Cp).reshape(B, nc, Q, ds)

    cum = jnp.cumsum(dA_c, axis=2)                               # [B,nc,Q,nh]
    # ---- intra-chunk (attention-like, exact for τ<=t) ----
    Lmat = cum[:, :, :, None, :] - cum[:, :, None, :, :]         # [B,nc,Q,Q,nh]
    tri = (jnp.arange(Q)[:, None] >= jnp.arange(Q)[None, :])
    decay = jnp.where(tri[None, None, :, :, None], jnp.exp(Lmat), 0.0)
    G = jnp.einsum("bnts,bnqs->bntq", C_c, B_c)                  # [B,nc,Q,Q]
    M = G[..., None] * decay                                     # [B,nc,Q,Q,nh]
    M = M * dt_c[:, :, None, :, :]                               # fold dt into B·x
    y_intra = jnp.einsum("bntqh,bnqhp->bnthp", M, x_c)
    # ---- chunk states ----
    last = cum[:, :, -1:, :]                                     # [B,nc,1,nh]
    sdecay = jnp.exp(last - cum)                                 # [B,nc,Q,nh]
    Sc = jnp.einsum("bnqs,bnqh,bnqhp->bnhsp",
                    B_c, sdecay * dt_c, x_c)                     # [B,nc,nh,ds,hp]
    # ---- inter-chunk recurrence over nc ----
    h0 = (jnp.zeros((B, nh, ds, hp), jnp.float32) if state is None
          else state.ssm.astype(jnp.float32))
    cdecay = jnp.exp(last[:, :, 0, :])                           # [B,nc,nh]

    def comb(l, r):
        aL, sL = l
        aR, sR = r
        return (aR * aL, aR[..., None, None] * sL + sR)

    accA, accS = jax.lax.associative_scan(comb, (cdecay, Sc), axis=1)
    # h_before_chunk_n = decay of all previous chunks applied to h0 + states
    accA_prev = jnp.concatenate(
        [jnp.ones_like(accA[:, :1]), accA[:, :-1]], axis=1)
    accS_prev = jnp.concatenate(
        [jnp.zeros_like(accS[:, :1]), accS[:, :-1]], axis=1)
    h_in = (accA_prev[..., None, None] * h0[:, None]
            + accS_prev)                                         # [B,nc,nh,ds,hp]
    # ---- inter-chunk contribution to outputs ----
    edecay = jnp.exp(cum)                                        # decay from chunk start
    y_inter = jnp.einsum("bnqs,bnqh,bnhsp->bnqhp", C_c, edecay, h_in)
    y = (y_intra + y_inter).reshape(B, nc * Q, nh, hp)[:, :S]
    y = y + xm.astype(jnp.float32) * p["D"][None, None, :, None]
    y = y.reshape(B, S, di).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z), p["norm_w"], cfg.norm_eps)
    out = y @ p["out_proj"]
    hT = accA[:, -1][..., None, None] * h0 + accS[:, -1]
    if conv_state is None:
        conv_state = jnp.zeros((B, di + 2 * ds, cfg.d_conv), x.dtype)
    return out, MambaState(conv_state.astype(x.dtype), hT)


def mamba2_decode(p: Params, cfg: ModelConfig, x, state: MambaState,
                  ) -> Tuple[jnp.ndarray, MambaState]:
    di, ds = cfg.d_inner, cfg.ssm_state
    nh, hp = cfg.n_ssm_heads, cfg.ssm_headdim
    B = x.shape[0]
    zxbcdt = x[:, 0] @ p["in_proj"]
    z = zxbcdt[..., :di]
    xbc = zxbcdt[..., di:di + di + 2 * ds]
    dt = jax.nn.softplus(zxbcdt[..., -nh:].astype(jnp.float32) + p["dt_b"])
    conv = jnp.concatenate([state.conv[:, :, 1:], xbc[:, :, None]], axis=-1)
    xbc = jnp.einsum("bck,kc->bc", conv, p["conv_w"]) + p["conv_b"]
    xbc = jax.nn.silu(xbc)
    xm = xbc[..., :di].reshape(B, nh, hp).astype(jnp.float32)
    Bp = xbc[..., di:di + ds].astype(jnp.float32)
    Cp = xbc[..., di + ds:].astype(jnp.float32)
    A = -jnp.exp(p["A_log"])
    a = jnp.exp(dt * A)                                          # [B,nh]
    h = a[..., None, None] * state.ssm \
        + jnp.einsum("bh,bs,bhp->bhsp", dt, Bp, xm)
    y = jnp.einsum("bs,bhsp->bhp", Cp, h) + xm * p["D"][None, :, None]
    y = y.reshape(B, di).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z), p["norm_w"], cfg.norm_eps)
    return (y @ p["out_proj"])[:, None], MambaState(conv, h)


def init_mamba_state(cfg: ModelConfig, B: int, n_layers: int,
                     dtype=jnp.bfloat16) -> MambaState:
    if cfg.ssm_version == 1:
        conv_dim, ssm_shape = cfg.d_inner, (cfg.d_inner, cfg.ssm_state)
    else:
        conv_dim = cfg.d_inner + 2 * cfg.ssm_state
        ssm_shape = (cfg.n_ssm_heads, cfg.ssm_headdim, cfg.ssm_state)
    return MambaState(
        jnp.zeros((n_layers, B, conv_dim, cfg.d_conv), dtype),
        jnp.zeros((n_layers, B) + ssm_shape, jnp.float32))
