"""Decoder/encoder block wiring for every architecture family.

A block = pre-norm mixer + residual (+ pre-norm FFN + residual when the
family has a separate FFN). Mixers: GQA attention, MLA, mamba1, mamba2.
FFNs: dense MLP variants or MoE. All block params are plain dicts so stacked
(scan-over-layers) initialization is just ``jax.vmap`` over keys.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from . import attention as A
from . import mamba as M
from . import mla as MLA
from . import moe as MOE
from .config import ModelConfig
from .layers import (Params, apply_norm, dense_init, mlp_apply, mlp_params,
                     norm_params)


def block_kinds(cfg: ModelConfig) -> Tuple[Tuple[str, int], ...]:
    """Layer-segment plan: ((kind, n_layers), ...) scanned homogeneously."""
    f = cfg.family
    if f in ("dense", "vlm"):
        return (("dense", cfg.n_layers),)
    if f == "moe":
        mixer = "mla" if cfg.attention == "mla" else "gqa"
        segs = []
        if cfg.n_dense_layers:
            segs.append((f"{mixer}+mlp", cfg.n_dense_layers))
        segs.append((f"{mixer}+moe", cfg.n_layers - cfg.n_dense_layers))
        return tuple(segs)
    if f == "ssm":
        return ((f"mamba{cfg.ssm_version}", cfg.n_layers),)
    if f == "hybrid":
        return (("hybrid", cfg.n_layers),)   # assembled specially in lm.py
    if f == "encdec":
        return (("encdec", cfg.n_layers),)
    raise ValueError(f)


# ------------------------------------------------------------------ params
def block_params(key, cfg: ModelConfig, kind: str) -> Params:
    d, dtype = cfg.d_model, cfg.dtype
    ks = jax.random.split(key, 4)
    p: Params = {"ln1": norm_params(ks[0], d, cfg.norm, dtype)}
    if kind in ("dense", "gqa+mlp", "gqa+moe"):
        p["attn"] = A.attn_params(ks[1], cfg)
    elif kind in ("mla+mlp", "mla+moe"):
        p["attn"] = MLA.mla_params(ks[1], cfg)
    elif kind == "mamba1":
        p["mixer"] = M.mamba1_params(ks[1], cfg)
        return p
    elif kind == "mamba2":
        p["mixer"] = M.mamba2_params(ks[1], cfg)
        return p
    p["ln2"] = norm_params(ks[2], d, cfg.norm, dtype)
    if kind.endswith("+moe"):
        p["moe"] = MOE.moe_params(ks[3], cfg)
    elif kind in ("dense", "gqa+mlp", "mla+mlp"):
        ff = cfg.d_ff_dense if (kind == "mla+mlp" and cfg.d_ff_dense) else cfg.d_ff
        p["mlp"] = mlp_params(ks[3], d, ff, cfg.mlp, dtype)
    return p


def enc_block_params(key, cfg: ModelConfig) -> Params:
    ks = jax.random.split(key, 4)
    d, dtype = cfg.d_model, cfg.dtype
    return {"ln1": norm_params(ks[0], d, cfg.norm, dtype),
            "attn": A.attn_params(ks[1], cfg),
            "ln2": norm_params(ks[2], d, cfg.norm, dtype),
            "mlp": mlp_params(ks[3], d, cfg.d_ff, cfg.mlp, dtype)}


def dec_block_params(key, cfg: ModelConfig) -> Params:
    ks = jax.random.split(key, 6)
    d, dtype = cfg.d_model, cfg.dtype
    return {"ln1": norm_params(ks[0], d, cfg.norm, dtype),
            "attn": A.attn_params(ks[1], cfg),
            "lnx": norm_params(ks[2], d, cfg.norm, dtype),
            "cross": A.cross_attn_params(ks[3], cfg),
            "ln2": norm_params(ks[4], d, cfg.norm, dtype),
            "mlp": mlp_params(ks[5], d, cfg.d_ff, cfg.mlp, dtype)}


# ------------------------------------------------------------------ forward
def block_forward(p: Params, cfg: ModelConfig, kind: str, x, positions, mask,
                  shard=lambda a, k: a):
    """Training/prefill-compute path (no cache). Returns (x, aux_loss)."""
    aux = jnp.float32(0.0)
    h = apply_norm(x, p["ln1"], cfg.norm, cfg.norm_eps)
    if kind in ("dense", "gqa+mlp", "gqa+moe"):
        x = x + shard(A.attn_forward(p["attn"], cfg, h, positions, mask), "bsd")
    elif kind in ("mla+mlp", "mla+moe"):
        x = x + shard(MLA.mla_forward(p["attn"], cfg, h, positions, mask), "bsd")
    elif kind == "mamba1":
        y, _ = M.mamba1_forward(p["mixer"], cfg, h)
        return x + shard(y, "bsd"), aux
    elif kind == "mamba2":
        y, _ = M.mamba2_forward(p["mixer"], cfg, h)
        return x + shard(y, "bsd"), aux
    h = apply_norm(x, p["ln2"], cfg.norm, cfg.norm_eps)
    if "moe" in p:
        y, aux = MOE.moe_apply(p["moe"], cfg, h, shard)
        x = x + shard(y, "bsd")
    else:
        x = x + shard(mlp_apply(p["mlp"], h, cfg.mlp), "bsd")
    return x, aux


def block_prefill(p, cfg: ModelConfig, kind: str, x, positions, mask,
                  cache_len: int, shard=lambda a, k: a):
    """Like block_forward but also emits this layer's decode cache."""
    h = apply_norm(x, p["ln1"], cfg.norm, cfg.norm_eps)
    if kind.startswith("mla"):
        y, cache = MLA.mla_prefill(p["attn"], cfg, h, positions, mask, cache_len)
        x = x + shard(y, "bsd")
    elif kind.startswith(("dense", "gqa")):
        y, cache = A.attn_prefill(p["attn"], cfg, h, positions, mask, cache_len)
        x = x + shard(y, "bsd")
    elif kind == "mamba1":
        y, cache = M.mamba1_forward(p["mixer"], cfg, h)
        return x + shard(y, "bsd"), cache
    elif kind == "mamba2":
        y, cache = M.mamba2_forward(p["mixer"], cfg, h)
        return x + shard(y, "bsd"), cache
    h = apply_norm(x, p["ln2"], cfg.norm, cfg.norm_eps)
    if "moe" in p:
        y, _ = MOE.moe_apply(p["moe"], cfg, h, shard)
        x = x + shard(y, "bsd")
    else:
        x = x + shard(mlp_apply(p["mlp"], h, cfg.mlp), "bsd")
    return x, cache


def block_decode(p, cfg: ModelConfig, kind: str, x, pos, cache,
                 shard=lambda a, k: a):
    """One-token step. cache is this layer's cache slice; returns (x, cache')."""
    h = apply_norm(x, p["ln1"], cfg.norm, cfg.norm_eps)
    if kind.startswith("mla"):
        y, cache = MLA.mla_decode(p["attn"], cfg, h, pos, cache)
        x = x + y
    elif kind.startswith(("dense", "gqa")):
        y, cache = A.attn_decode(p["attn"], cfg, h, pos, cache)
        x = x + y
    elif kind == "mamba1":
        y, cache = M.mamba1_decode(p["mixer"], cfg, h, cache)
        return x + y, cache
    elif kind == "mamba2":
        y, cache = M.mamba2_decode(p["mixer"], cfg, h, cache)
        return x + y, cache
    h = apply_norm(x, p["ln2"], cfg.norm, cfg.norm_eps)
    if "moe" in p:
        y, _ = MOE.moe_apply(p["moe"], cfg, h, shard)
        x = x + y
    else:
        x = x + mlp_apply(p["mlp"], h, cfg.mlp)
    return x, cache


# ------------------------------------------------------------- zamba2 shared
def shared_block_params(key, cfg: ModelConfig) -> Params:
    """The single shared attention+MLP block (zamba2)."""
    return block_params(key, cfg, "dense")


def shared_lora_params(key, cfg: ModelConfig) -> Params:
    """Per-occurrence LoRA adapters on the shared block's wq (simplified
    faithful: zamba2 attaches LoRA to the shared block per occurrence)."""
    r = cfg.shared_lora_rank
    d, H, hd = cfg.d_model, cfg.n_heads, cfg.hd
    k1, k2 = jax.random.split(key)
    return {"qa": dense_init(k1, d, r, dtype=cfg.dtype),
            "qb": jnp.zeros((r, H * hd), cfg.dtype)}


def shared_block_forward(p: Params, lora: Optional[Params], cfg: ModelConfig,
                         x, positions, mask, shard=lambda a, k: a):
    h = apply_norm(x, p["ln1"], cfg.norm, cfg.norm_eps)
    y = A.attn_forward(p["attn"], cfg, h, positions, mask)
    if lora is not None:
        B, S, d = h.shape
        # LoRA correction joins through the output projection
        dq = (h @ lora["qa"]) @ lora["qb"]
        y = y + A.proj_out(dq, p["attn"]["wo"])
    x = x + shard(y, "bsd")
    h = apply_norm(x, p["ln2"], cfg.norm, cfg.norm_eps)
    return x + shard(mlp_apply(p["mlp"], h, cfg.mlp), "bsd")


def shared_block_decode(p, lora, cfg: ModelConfig, x, pos, cache):
    h = apply_norm(x, p["ln1"], cfg.norm, cfg.norm_eps)
    y, cache = A.attn_decode(p["attn"], cfg, h, pos, cache)
    if lora is not None:
        B = h.shape[0]
        dq = (h @ lora["qa"]) @ lora["qb"]     # h is [B,1,d] in decode
        y = y + A.proj_out(dq, p["attn"]["wo"])
    x = x + y
    h = apply_norm(x, p["ln2"], cfg.norm, cfg.norm_eps)
    return x + mlp_apply(p["mlp"], h, cfg.mlp), cache
