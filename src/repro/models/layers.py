"""Shared layer primitives: inits, norms, MLPs, rotary embeddings.

Parameters are plain nested dicts of jnp arrays; every init function is pure
(usable under ``jax.eval_shape`` so the dry-run never materializes weights).
Compute runs in bfloat16 with float32 softmax/norm accumulations.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

Params = Dict[str, object]


def dense_init(key, d_in: int, *dims, dtype, scale: float = 1.0):
    """Truncated-normal fan-in init, shape [d_in, *dims]."""
    shape = (d_in,) + dims
    std = scale / (d_in ** 0.5)
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)
            * std).astype(dtype)


def embed_init(key, vocab: int, d: int, dtype):
    return (jax.random.truncated_normal(key, -2.0, 2.0, (vocab, d), jnp.float32)
            * 0.02).astype(dtype)


# ------------------------------------------------------------------ norms
def rms_norm(x, w, eps: float):
    h = x.astype(jnp.float32)
    h = h * jax.lax.rsqrt(jnp.mean(h * h, axis=-1, keepdims=True) + eps)
    return (h * (1.0 + w.astype(jnp.float32))).astype(x.dtype)


def layer_norm(x, w, b, eps: float):
    h = x.astype(jnp.float32)
    mu = jnp.mean(h, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(h - mu), axis=-1, keepdims=True)
    h = (h - mu) * jax.lax.rsqrt(var + eps)
    return (h * w.astype(jnp.float32) + b.astype(jnp.float32)).astype(x.dtype)


def norm_params(key, d: int, kind: str, dtype) -> Params:
    if kind == "rms":
        return {"w": jnp.zeros((d,), dtype)}
    return {"w": jnp.ones((d,), dtype), "b": jnp.zeros((d,), dtype)}


def apply_norm(x, p: Params, kind: str, eps: float):
    if kind == "rms":
        return rms_norm(x, p["w"], eps)
    return layer_norm(x, p["w"], p["b"], eps)


# ------------------------------------------------------------------ MLPs
def mlp_params(key, d: int, ff: int, kind: str, dtype) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    if kind in ("swiglu", "geglu"):
        return {"wi": dense_init(k1, d, ff, dtype=dtype),
                "wg": dense_init(k2, d, ff, dtype=dtype),
                "wo": dense_init(k3, ff, d, dtype=dtype)}
    return {"wi": dense_init(k1, d, ff, dtype=dtype),
            "wo": dense_init(k2, ff, d, dtype=dtype)}


def mlp_apply(p: Params, x, kind: str):
    if kind == "swiglu":
        h = jax.nn.silu(x @ p["wg"]) * (x @ p["wi"])
    elif kind == "geglu":
        h = jax.nn.gelu(x @ p["wg"], approximate=True) * (x @ p["wi"])
    elif kind == "relu2":   # nemotron squared-ReLU
        h = jnp.square(jax.nn.relu(x @ p["wi"]))
    else:                   # gelu (whisper)
        h = jax.nn.gelu(x @ p["wi"], approximate=True)
    return h @ p["wo"]


# ------------------------------------------------------------------ rotary
def rope_freqs(positions, head_dim: int, theta: float):
    """positions [...,] -> (sin, cos) each [..., head_dim/2] float32."""
    half = head_dim // 2
    inv = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[..., None] * inv
    return jnp.sin(ang), jnp.cos(ang)


def rope_apply(x, sin, cos):
    """x [..., S, n, head_dim]; sin/cos [..., S, head_dim/2] (broadcast on n)."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    sin = sin[..., None, :]
    cos = cos[..., None, :]
    x1f, x2f = x1.astype(jnp.float32), x2.astype(jnp.float32)
    return jnp.concatenate([x1f * cos - x2f * sin,
                            x2f * cos + x1f * sin], axis=-1).astype(x.dtype)


def learned_pos_params(key, max_pos: int, d: int, dtype) -> Params:
    return {"pos": dense_init(key, max_pos, d, dtype=dtype)}


# ------------------------------------------------------------------ loss
def softmax_xent(logits, labels, mask=None):
    """Cross entropy with f32 logsumexp; logits may be vocab-sharded.

    logits [..., V] (any float dtype), labels int32 [...]. Returns mean loss.
    """
    lf = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(lf, axis=-1)
    lab = jnp.take_along_axis(lf, labels[..., None].astype(jnp.int32),
                              axis=-1)[..., 0]
    nll = lse - lab
    if mask is not None:
        m = mask.astype(jnp.float32)
        return jnp.sum(nll * m) / jnp.maximum(jnp.sum(m), 1.0)
    return jnp.mean(nll)
