"""Multi-head Latent Attention (DeepSeek-V3) with absorbed-latent decode.

Train/prefill: standard MLA — queries via low-rank q projection, keys/values
up-projected from a compressed latent c_kv; a single shared rotary key head.
Decode: the cache holds only (c_kv, k_rope) per position ([kv_lora + rope]
floats/token — the paper point of MLA); W_uk is absorbed into the query and
W_uv into the output so attention runs entirely in latent space.
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import Params, dense_init, rms_norm, rope_apply, rope_freqs


class MLACache(NamedTuple):
    ckv: jnp.ndarray     # [B, S_max, kv_lora]
    krope: jnp.ndarray   # [B, S_max, rope_dim]


def mla_params(key, cfg: ModelConfig, dtype=None) -> Params:
    dtype = dtype or cfg.dtype
    d, H = cfg.d_model, cfg.n_heads
    qr, kr = cfg.q_lora_rank, cfg.kv_lora_rank
    nope, rope, vh = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    ks = jax.random.split(key, 8)
    return {
        "wdq": dense_init(ks[0], d, qr, dtype=dtype),
        "qnorm": {"w": jnp.zeros((qr,), dtype)},
        "wuq": dense_init(ks[1], qr, H, nope + rope, dtype=dtype),
        "wdkv": dense_init(ks[2], d, kr, dtype=dtype),
        "kvnorm": {"w": jnp.zeros((kr,), dtype)},
        "wkr": dense_init(ks[3], d, rope, dtype=dtype),
        "wukv": dense_init(ks[4], kr, H, nope + vh, dtype=dtype),
        "wo": (jax.random.truncated_normal(ks[5], -2.0, 2.0, (H, vh, d),
                                           jnp.float32)
               * ((H * vh) ** -0.5)).astype(dtype),
    }


def _queries(p, cfg: ModelConfig, x, positions):
    nope, rope = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim
    q = rms_norm(x @ p["wdq"], p["qnorm"]["w"], cfg.norm_eps)
    q = jnp.einsum("bsr,rnh->bsnh", q, p["wuq"])
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    sin, cos = rope_freqs(positions, rope, cfg.rope_theta)
    q_rope = rope_apply(q_rope, sin, cos)
    return q_nope, q_rope


def _latents(p, cfg: ModelConfig, x, positions):
    rope = cfg.qk_rope_head_dim
    ckv = rms_norm(x @ p["wdkv"], p["kvnorm"]["w"], cfg.norm_eps)
    kr = (x @ p["wkr"])[:, :, None, :]                   # [B,S,1,rope]
    sin, cos = rope_freqs(positions, rope, cfg.rope_theta)
    kr = rope_apply(kr, sin, cos)[:, :, 0, :]
    return ckv, kr


def mla_forward(p: Params, cfg: ModelConfig, x, positions, mask) -> jnp.ndarray:
    """Full-sequence MLA (training / prefill compute).

    Folded into standard attention by concatenating the rotary slice onto
    every head's nope slice — the shared rotary key broadcasts across heads —
    so the flash-tiled sdpa path applies unchanged (mask is a MaskSpec).
    """
    from .attention import sdpa
    nope, vh = cfg.qk_nope_head_dim, cfg.v_head_dim
    B, S, _ = x.shape
    H = cfg.n_heads
    q_nope, q_rope = _queries(p, cfg, x, positions)
    ckv, kr = _latents(p, cfg, x, positions)
    kv = jnp.einsum("btr,rnh->btnh", ckv, p["wukv"])
    k_nope, v = kv[..., :nope], kv[..., nope:]
    q_cat = jnp.concatenate([q_nope, q_rope], axis=-1)
    k_cat = jnp.concatenate(
        [k_nope, jnp.broadcast_to(kr[:, :, None, :], k_nope.shape[:3]
                                  + (cfg.qk_rope_head_dim,))], axis=-1)
    scale = (nope + cfg.qk_rope_head_dim) ** -0.5
    out = sdpa(q_cat, k_cat, v, mask, 1, scale=scale)
    from .attention import proj_out
    return proj_out(out, p["wo"])


def mla_prefill(p, cfg, x, positions, mask, cache_len: int,
                ) -> Tuple[jnp.ndarray, MLACache]:
    y = mla_forward(p, cfg, x, positions, mask)
    ckv, kr = _latents(p, cfg, x, positions)
    S = x.shape[1]
    pad = [(0, 0), (0, cache_len - S), (0, 0)]
    return y, MLACache(jnp.pad(ckv, pad).astype(jnp.bfloat16),
                       jnp.pad(kr, pad).astype(jnp.bfloat16))


def mla_decode(p: Params, cfg: ModelConfig, x, pos, cache: MLACache,
               ) -> Tuple[jnp.ndarray, MLACache]:
    """Absorbed-latent one-token decode. x [B,1,d], pos [B]."""
    nope, vh = cfg.qk_nope_head_dim, cfg.v_head_dim
    B = x.shape[0]
    q_nope, q_rope = _queries(p, cfg, x, pos[:, None])   # [B,1,H,·]
    ckv_t, kr_t = _latents(p, cfg, x, pos[:, None])      # [B,1,kr], [B,1,rope]
    bidx = jnp.arange(B)
    ckv = cache.ckv.at[bidx, pos].set(ckv_t[:, 0].astype(cache.ckv.dtype))
    krope = cache.krope.at[bidx, pos].set(kr_t[:, 0].astype(cache.krope.dtype))

    wuk = p["wukv"][..., :nope]                          # [kr, H, nope]
    wuv = p["wukv"][..., nope:]                          # [kr, H, vh]
    q_lat = jnp.einsum("bnh,rnh->bnr", q_nope[:, 0], wuk)      # absorb W_uk
    scale = (nope + cfg.qk_rope_head_dim) ** -0.5
    logits = (jnp.einsum("bnr,btr->bnt", q_lat, ckv.astype(x.dtype),
                         preferred_element_type=jnp.float32)
              + jnp.einsum("bnh,bth->bnt", q_rope[:, 0], krope.astype(x.dtype),
                           preferred_element_type=jnp.float32)) * scale
    valid = jnp.arange(ckv.shape[1])[None, None, :] <= pos[:, None, None]
    w = jax.nn.softmax(jnp.where(valid, logits, -1e30), axis=-1)
    lat = jnp.einsum("bnt,btr->bnr", w.astype(x.dtype), ckv.astype(x.dtype))
    out = jnp.einsum("bnr,rnh->bnh", lat, wuv)           # absorb W_uv
    y = jnp.einsum("bnh,nhd->bd", out, p["wo"])[:, None]
    return y, MLACache(ckv, krope)
