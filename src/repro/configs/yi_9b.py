"""yi-9b [dense] — llama-architecture GQA.

48L d_model=4096 32H (GQA kv=4) d_ff=11008 vocab=64000
[arXiv:2403.04652; hf].
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="yi-9b", family="dense",
    n_layers=48, d_model=4096, vocab=64000,
    n_heads=32, n_kv_heads=4, head_dim=128,
    d_ff=11008, mlp="swiglu", norm="rms",
    rope_theta=10_000.0, tie_embeddings=False,
)

SMOKE = ModelConfig(
    name="yi-smoke", family="dense",
    n_layers=2, d_model=64, vocab=512,
    n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=96, mlp="swiglu", norm="rms", tie_embeddings=False,
)
