"""whisper-medium [audio] — encoder-decoder transformer backbone.

24L(enc) + 24L(dec) d_model=1024 16H (MHA kv=16) d_ff=4096 vocab=51865
[arXiv:2212.04356; unverified]. The conv/mel frontend is a stub:
``input_specs`` supplies precomputed frame embeddings (width 128); learned
position tables are sized to the assigned 32k shapes (adaptation noted in
DESIGN.md — original Whisper caps at 1500 frames / 448 tokens).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-medium", family="encdec",
    n_layers=24, n_enc_layers=24, d_model=1024, vocab=51865,
    n_heads=16, n_kv_heads=16, head_dim=64,
    d_ff=4096, mlp="gelu", norm="ln", pos="learned",
    tie_embeddings=True,
    enc_seq=32768, frontend_dim=128,
)

SMOKE = ModelConfig(
    name="whisper-smoke", family="encdec",
    n_layers=2, n_enc_layers=2, d_model=64, vocab=512,
    n_heads=4, n_kv_heads=4, head_dim=16,
    d_ff=128, mlp="gelu", norm="ln", pos="learned",
    tie_embeddings=True,
    enc_seq=64, frontend_dim=24,
)
