"""qwen2.5-14b [dense] — GQA with QKV bias.

48L d_model=5120 40H (GQA kv=8) d_ff=13824 vocab=152064
[hf:Qwen/Qwen2.5-0.5B family; hf].
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2.5-14b", family="dense",
    n_layers=48, d_model=5120, vocab=152064,
    n_heads=40, n_kv_heads=8, head_dim=128,
    d_ff=13824, mlp="swiglu", norm="rms",
    qkv_bias=True, rope_theta=1_000_000.0, tie_embeddings=False,
)

SMOKE = ModelConfig(
    name="qwen2.5-smoke", family="dense",
    n_layers=2, d_model=64, vocab=512,
    n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=160, mlp="swiglu", norm="rms",
    qkv_bias=True, tie_embeddings=False,
)
