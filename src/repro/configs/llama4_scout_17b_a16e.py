"""llama4-scout-17b-a16e [moe] — 16 routed experts, top-1, + shared expert.

48L d_model=5120 40H (GQA kv=8) d_ff=8192(expert) vocab=202048, MoE 16e top-1
[hf:meta-llama/Llama-4-Scout-17B-16E; unverified]. Early-fusion multimodality
is out of scope for the LM backbone cell (text path only, per assignment).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama4-scout-17b-a16e", family="moe",
    n_layers=48, d_model=5120, vocab=202048,
    n_heads=40, n_kv_heads=8, head_dim=128,
    d_ff=8192, mlp="swiglu", norm="rms",
    rope_theta=500_000.0, tie_embeddings=False,
    n_experts=16, top_k=1, n_shared_experts=1, d_ff_expert=8192,
    router="softmax", capacity_factor=1.25, moe_impl="gshard",
)

SMOKE = ModelConfig(
    name="llama4-scout-smoke", family="moe",
    n_layers=2, d_model=64, vocab=512,
    n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=96, mlp="swiglu", norm="rms", tie_embeddings=False,
    n_experts=4, top_k=1, n_shared_experts=1, d_ff_expert=96,
    router="softmax", moe_impl="scatter",
)
