"""paligemma-3b [vlm] — SigLIP patch-embedding stub + gemma-style decoder.

18L d_model=2048 8H (GQA kv=1, head_dim=256) d_ff=16384 vocab=257216
[arXiv:2407.07726; hf]. The SigLIP frontend is a stub: ``input_specs``
supplies precomputed patch embeddings (width 1152 = SigLIP-So400m); the
backbone projects and prepends them with a bidirectional prefix-LM mask.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="paligemma-3b", family="vlm",
    n_layers=18, d_model=2048, vocab=257216,
    n_heads=8, n_kv_heads=1, head_dim=256,
    d_ff=16384, mlp="geglu", norm="rms",
    rope_theta=10_000.0, tie_embeddings=True,
    n_patches=256, frontend_dim=1152,
)

SMOKE = ModelConfig(
    name="paligemma-smoke", family="vlm",
    n_layers=2, d_model=64, vocab=512,
    n_heads=4, n_kv_heads=1, head_dim=16,
    d_ff=128, mlp="geglu", norm="rms", tie_embeddings=True,
    n_patches=8, frontend_dim=24,
)
