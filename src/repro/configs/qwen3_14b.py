"""qwen3-14b [dense] — GQA with per-head qk RMS-norm, no bias.

40L d_model=5120 40H (GQA kv=8) d_ff=17408 vocab=151936
[hf:Qwen/Qwen3-8B family; hf].
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-14b", family="dense",
    n_layers=40, d_model=5120, vocab=151936,
    n_heads=40, n_kv_heads=8, head_dim=128,
    d_ff=17408, mlp="swiglu", norm="rms",
    qk_norm=True, rope_theta=1_000_000.0, tie_embeddings=False,
)

SMOKE = ModelConfig(
    name="qwen3-smoke", family="dense",
    n_layers=2, d_model=64, vocab=512,
    n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=160, mlp="swiglu", norm="rms",
    qk_norm=True, tie_embeddings=False,
)
