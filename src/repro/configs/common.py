"""Shared shape-suite definitions and input specs for the assigned cells.

Every architecture is paired with the LM shape set:
    train_4k     seq 4096,   global_batch 256   (training)
    prefill_32k  seq 32768,  global_batch 32    (inference prefill)
    decode_32k   seq 32768,  global_batch 128   (one-token decode, full cache)
    long_500k    seq 524288, global_batch 1     (long-context decode;
                                                 SSM/hybrid archs only)

``input_specs`` returns ShapeDtypeStruct stand-ins only — nothing is ever
allocated; the dry-run lowers against these. Modality frontends are stubs:
[vlm] supplies precomputed patch embeddings, [audio] precomputed frame
embeddings (per the assignment brief).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig

SDS = jax.ShapeDtypeStruct


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str              # train | prefill | decode


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}


def applicable(cfg: ModelConfig, shape: str) -> Tuple[bool, str]:
    """(runs?, reason). long_500k needs sub-quadratic attention."""
    if shape == "long_500k" and cfg.family not in ("ssm", "hybrid"):
        return False, ("full-attention arch: 500k decode would need a 500k "
                       "dense KV per layer and quadratic prefill — skipped "
                       "per brief (run for SSM/hybrid only)")
    return True, ""


def input_specs(cfg: ModelConfig, shape: str) -> Dict[str, SDS]:
    """Model-input stand-ins for one (arch × shape) cell."""
    sp = SHAPES[shape]
    B, S = sp.global_batch, sp.seq_len
    i32 = jnp.int32
    if sp.kind in ("train", "prefill"):
        if cfg.family == "vlm":
            # patch prefix + text fill the assigned sequence length
            s_txt = S - cfg.n_patches
            return {"tokens": SDS((B, s_txt), i32),
                    "patches": SDS((B, cfg.n_patches, cfg.frontend_dim),
                                   jnp.bfloat16)}
        if cfg.family == "encdec":
            return {"tokens": SDS((B, S), i32),
                    "frames": SDS((B, S, cfg.frontend_dim), jnp.bfloat16)}
        return {"tokens": SDS((B, S), i32)}
    # decode: one new token against a cache of S positions
    specs = {"tokens": SDS((B,), i32), "pos": SDS((B,), i32)}
    return specs


def smoke_batch(cfg: ModelConfig, B: int = 2, S: int = 32, seed: int = 0):
    """Small concrete batch for CPU smoke tests (reduced configs)."""
    rng = jax.random.PRNGKey(seed)
    r1, r2, r3 = jax.random.split(rng, 3)
    batch = {"tokens": jax.random.randint(r1, (B, S), 0, cfg.vocab, jnp.int32)}
    if cfg.family == "vlm":
        batch["patches"] = jax.random.normal(
            r2, (B, cfg.n_patches, cfg.frontend_dim), jnp.bfloat16)
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(
            r3, (B, S, cfg.frontend_dim), jnp.bfloat16)
    return batch
