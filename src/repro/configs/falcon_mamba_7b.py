"""falcon-mamba-7b [ssm] — attention-free Mamba-1 stack.

64L d_model=4096 (attn-free) vocab=65024, ssm_state=16, expand=2, d_conv=4,
dt_rank=256 [arXiv:2410.05355; unverified]. Runs the long_500k shape (O(1)
decode state; no KV cache).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="falcon-mamba-7b", family="ssm",
    n_layers=64, d_model=4096, vocab=65024,
    d_ff=0, norm="rms", tie_embeddings=True,
    ssm_state=16, ssm_version=1, d_conv=4, expand=2, dt_rank=256,
)

SMOKE = ModelConfig(
    name="falcon-mamba-smoke", family="ssm",
    n_layers=2, d_model=64, vocab=512,
    d_ff=0, norm="rms", tie_embeddings=True,
    ssm_state=8, ssm_version=1, d_conv=4, expand=2, dt_rank=8,
)
