"""zamba2-7b [hybrid] — Mamba-2 backbone with a shared attention block.

81L d_model=3584 32H (GQA kv=32) d_ff=14336 vocab=32000, ssm_state=64
[arXiv:2411.15242; unverified]. Every 6th block applies the single *shared*
attention+MLP block (params reused across its 13 occurrences, per-occurrence
LoRA rank 128 on wq — simplified-faithful to zamba2's shared-block design).
Runs long_500k: mamba decode is O(1); shared-attn decode is linear in cache.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-7b", family="hybrid",
    n_layers=81, d_model=3584, vocab=32000,
    n_heads=32, n_kv_heads=32, head_dim=112,
    d_ff=14336, mlp="swiglu", norm="rms",
    rope_theta=10_000.0, tie_embeddings=True,
    ssm_state=64, ssm_version=2, d_conv=4, expand=2, ssm_headdim=64,
    shared_attn_period=6, shared_lora_rank=128,
)

SMOKE = ModelConfig(
    name="zamba2-smoke", family="hybrid",
    n_layers=7, d_model=64, vocab=512,
    n_heads=4, n_kv_heads=4, head_dim=16,
    d_ff=128, mlp="swiglu", norm="rms", tie_embeddings=True,
    ssm_state=8, ssm_version=2, d_conv=4, expand=2, ssm_headdim=16,
    shared_attn_period=3, shared_lora_rank=8,
)
