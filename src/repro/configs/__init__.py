"""Architecture registry: ``--arch <id>`` resolves here."""
from __future__ import annotations

import importlib
from typing import Dict

from repro.models.config import ModelConfig

from .common import SHAPES, ShapeSpec, applicable, input_specs, smoke_batch

# arch id -> module name
_MODULES: Dict[str, str] = {
    "paligemma-3b": "paligemma_3b",
    "qwen2.5-14b": "qwen2_5_14b",
    "nemotron-4-15b": "nemotron_4_15b",
    "qwen3-14b": "qwen3_14b",
    "yi-9b": "yi_9b",
    "llama4-scout-17b-a16e": "llama4_scout_17b_a16e",
    "deepseek-v3-671b": "deepseek_v3_671b",
    "whisper-medium": "whisper_medium",
    "falcon-mamba-7b": "falcon_mamba_7b",
    "zamba2-7b": "zamba2_7b",
}

ARCH_IDS = tuple(_MODULES)


def get_config(arch: str, smoke: bool = False, **overrides) -> ModelConfig:
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {list(_MODULES)}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch]}")
    cfg = mod.SMOKE if smoke else mod.CONFIG
    if overrides:
        import dataclasses
        cfg = dataclasses.replace(cfg, **overrides)
    return cfg


__all__ = ["ARCH_IDS", "SHAPES", "ShapeSpec", "applicable", "get_config",
           "input_specs", "smoke_batch"]
