"""nemotron-4-15b [dense] — GQA with squared-ReLU MLP, LayerNorm.

32L d_model=6144 48H (GQA kv=8) d_ff=24576 vocab=256000
[arXiv:2402.16819; unverified].
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="nemotron-4-15b", family="dense",
    n_layers=32, d_model=6144, vocab=256000,
    n_heads=48, n_kv_heads=8, head_dim=128,
    d_ff=24576, mlp="relu2", norm="ln",
    rope_theta=10_000.0, tie_embeddings=False,
)

SMOKE = ModelConfig(
    name="nemotron-smoke", family="dense",
    n_layers=2, d_model=64, vocab=512,
    n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=192, mlp="relu2", norm="ln", tie_embeddings=False,
)
