"""deepseek-v3-671b [moe] — MLA, 1 shared + 256 routed top-8, MTP.

61L d_model=7168 128H (MLA: q_lora 1536, kv_lora 512, nope 128, rope 64,
v 128) d_ff=2048(expert) vocab=129280, 3 leading dense layers (d_ff 18432),
sigmoid router with bias-corrected aux-loss-free top-8
[arXiv:2412.19437; hf].
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v3-671b", family="moe",
    n_layers=61, d_model=7168, vocab=129280,
    n_heads=128, n_kv_heads=128,
    attention="mla",
    q_lora_rank=1536, kv_lora_rank=512,
    qk_nope_head_dim=128, qk_rope_head_dim=64, v_head_dim=128,
    d_ff=2048, mlp="swiglu", norm="rms",
    rope_theta=10_000.0, tie_embeddings=False,
    n_experts=256, top_k=8, n_shared_experts=1, d_ff_expert=2048,
    n_dense_layers=3, d_ff_dense=18432,
    router="sigmoid", capacity_factor=1.25, moe_impl="gshard",
    mtp=True, mtp_weight=0.1,
)

SMOKE = ModelConfig(
    name="deepseek-v3-smoke", family="moe",
    n_layers=3, d_model=64, vocab=512,
    n_heads=4, n_kv_heads=4,
    attention="mla",
    q_lora_rank=32, kv_lora_rank=16,
    qk_nope_head_dim=16, qk_rope_head_dim=8, v_head_dim=16,
    d_ff=64, mlp="swiglu", norm="rms", tie_embeddings=False,
    n_experts=4, top_k=2, n_shared_experts=1, d_ff_expert=64,
    n_dense_layers=1, d_ff_dense=128,
    router="sigmoid", moe_impl="scatter",
    mtp=True, mtp_weight=0.1,
)
