"""jnp oracle for the fused range scan: the chain-walk reference lives in
``core.batch_ops._range_scan_jnp`` (single definition — it IS the fallback
path ``range_scan`` runs for every non-kernel backend). This thin wrapper
pins it to the ``jnp`` descent so kernel-level tests can compare the kernel
against a fixed reference configuration regardless of which engine the
caller would select (``tests/test_scan.py::test_scan_registry``).
"""
from __future__ import annotations


def fused_range_scan_ref(tree, qb, ql, max_items: int = 64,
                         collect_stats: bool = True):
    from repro.core.batch_ops import _range_scan_jnp
    from repro.core.traverse import TraversalEngine
    eng = TraversalEngine("jnp", collect_stats=collect_stats)
    return _range_scan_jnp(tree, qb, ql, max_items, eng)
