"""Engine-facing wrapper for the fused range-scan kernel.

Registered as the ``"fused"`` scan backend in ``core.traverse``
(DESIGN.md §6): :func:`fused_range_scan` matches the ScanBackend signature,
so ``core.batch_ops.range_scan`` collapses the whole scan — descent, sibling
hop, and the leaf-chain walk with lazy-rearrangement sorting — into one
kernel launch whenever the engine's backend is ``"fused"``. Emitted
``(key_id, value)`` pairs are bit-identical to the jnp chain-walk reference
(the scan parity suite pins this); the ``rearranged`` counter is compiled
out entirely when ``collect_stats`` is off.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core.fbtree import FBTree

from .kernel import descent_tile, fused_scan_kernel


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def fused_range_scan(tree: FBTree, qb, ql, max_items: int = 64,
                     collect_stats: bool = True,
                     ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray,
                                jnp.ndarray]:
    """Scan-backend entry: whole range scan in one kernel launch.

    Returns ``(out_kid [B, max_items], out_val [B, max_items], emitted [B],
    rearranged [B])`` — the ``core.batch_ops.range_scan`` contract.
    ``rearranged`` is all-zero (and never traced in-kernel) when
    ``collect_stats`` is off.
    """
    a = tree.arrays
    s = a.stacked
    n_levels = len(a.levels)
    fs = s.features.shape[-2]
    ns = s.features.shape[-1]
    B, L = qb.shape

    tile_b = descent_tile(B, ns)
    Bp = -(-B // tile_b) * tile_b
    qb_p, ql_p = qb, ql
    if Bp != B:
        # pad with +inf-like queries (0xff.., full length): padded lanes
        # land on the last leaf, emit nothing, and retire on hop 0
        qb_p = jnp.concatenate(
            [qb, jnp.full((Bp - B, L), 0xFF, jnp.uint8)], axis=0)
        ql_p = jnp.concatenate(
            [ql, jnp.full((Bp - B,), L, ql.dtype)], axis=0)

    stacked_arrays = (s.knum, s.plen, s.prefix, s.features, s.children,
                      s.anchors)
    leaf_arrays = (a.leaf_high[:, None], a.leaf_next[:, None], a.leaf_keyid,
                   a.leaf_val, a.leaf_occ.astype(jnp.uint8),
                   a.leaf_ordered.astype(jnp.uint8)[:, None])

    outs = fused_scan_kernel(
        qb_p, ql_p[:, None], stacked_arrays, a.key_bytes,
        a.key_lens[:, None], leaf_arrays, tile_b=tile_b, n_levels=n_levels,
        fs=fs, ns=ns, max_items=max_items, collect_stats=collect_stats,
        interpret=not _on_tpu())
    outs = [o[:B] for o in outs]
    out_kid, out_val = outs[0], outs[1]
    emitted = outs[2][:, 0]
    rearranged = (outs[3][:, 0] if collect_stats
                  else jnp.zeros((B,), jnp.int32))
    return out_kid, out_val, emitted, rearranged
