"""Pallas TPU kernel: batched range scan — descent + leaf-chain walk in ONE
launch (the YCSB-E hot path, paper Fig. 17).

The jnp reference (``core.batch_ops._range_scan_jnp``) relaunches a gather +
sort + scatter pipeline per sibling hop through XLA; the level-wise
batch-search designs (BS-tree, the FPGA batch scan) show the win comes from
keeping the walk resident. This kernel tiles the *query batch* over the grid
and runs the whole scan inside the kernel body:

  1. the root→leaf descent — ``descend_levels`` + ``sibling_hop``, SHARED
     with ``kernels/fused_descent`` so both kernels resolve bit-identical
     start leaves (stats-free: ``range_scan`` never returns BranchStats);
  2. a peeled hop 0 with the in-kernel start-key compare — the ONLY hop
     that gathers key bytes unconditionally;
  3. an early-exit ``while_loop`` over the sibling chain: every key of an
     active leaf emits (the chain ascends, so hop ≥ 1 keys are all ≥ the
     start key), lanes retire as they hit ``max_items`` or chain end.

Lazy rearrangement (paper §4.5) in-kernel: each hop's emission order comes
from a ``lax.cond`` — when every active lane's leaf has its ``leaf_ordered``
bit set, ranks are a plain occupancy cumsum (no key traffic at all); only a
dirty leaf pays the rank-by-count sort. Sorting is *rank-by-count* rather
than argsort (rank(j) = #{emitted i : key_i < key_j} over order-preserving
packed words): tree keys are unique, so strict 'less' reproduces the jnp
reference's stable lexsort emission order bit for bit, and the [TB, ns, ns]
compare is a vector reduction instead of a data-dependent permutation.

Emission is scatter-free: a slot with in-row rank r lands at output column
``emitted + r`` via a one-hot reduction over the slot axis (`_merge_emit`) —
destination positions are unique per row, so the reduction is exact. Static
``collect_stats`` drops the ``rearranged`` accumulator and output from the
compiled kernel; emitted pairs are bit-identical either way.

Off-TPU this runs in interpret mode like every kernel in the repo; tree
state rides in whole-array blocks (a real-TPU deployment would stream the
chain through double-buffered leaf blocks).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.keys import pack_words_j

from ..fused_descent.kernel import (_cmp3, descend_levels, descent_tile,
                                    sibling_hop)

__all__ = ["fused_scan_kernel", "descent_tile"]


def _iota(shape, dim):
    return jax.lax.broadcasted_iota(jnp.int32, shape, dim)


def _cmp3_slots(kb, kl, qb, ql):
    """3-way compare of every leaf-slot key against its lane's query:
    ``kb [TB, ns, L]`` / ``kl [TB, ns]`` vs ``qb [TB, L]`` / ``ql [TB, 1]``
    → ``[TB, ns]``. Flattens slots into rows and defers to the shared
    ``fused_descent._cmp3`` — one definition of the parity-critical padded
    compare (bytes first, length tie-break)."""
    TB, ns, L = kb.shape
    qb_rows = jnp.broadcast_to(qb[:, None, :], (TB, ns, L)).reshape(-1, L)
    ql_rows = jnp.broadcast_to(ql, (TB, ns)).reshape(-1, 1)
    c3 = _cmp3(kb.reshape(-1, L), kl.reshape(-1, 1), qb_rows, ql_rows)
    return c3.reshape(TB, ns)


def _rank_among(kb, kl, emit):
    """Ascending rank of each emitted slot among its row's emitted slots.

    rank(j) = #{emitted i : key_i < key_j}, computed over order-preserving
    packed words. Tree keys are unique, so the strict compare reproduces the
    jnp reference's stable lexsort order exactly. [TB, ns] int32.
    """
    words = pack_words_j(kb)                      # [TB, ns, W]
    TB, ns = emit.shape
    lt = jnp.zeros((TB, ns, ns), bool)
    eq = jnp.ones((TB, ns, ns), bool)
    for w in range(words.shape[-1]):
        aw = words[..., w]
        lt = lt | (eq & (aw[:, :, None] < aw[:, None, :]))
        eq = eq & (aw[:, :, None] == aw[:, None, :])
    lt = lt | (eq & (kl[:, :, None] < kl[:, None, :]))
    return jnp.sum((emit[:, :, None] & lt).astype(jnp.int32), axis=1)


def _merge_emit(out_kid, out_val, emitted, kid, val, emit, rank,
                max_items: int):
    """Scatter-free merge of one leaf's emitted slots into the output block.

    The slot with in-row rank r lands at column ``emitted + r`` through a
    one-hot reduction over the slot axis — destinations are unique per row,
    columns ≥ ``max_items`` fall off the iota and are dropped, matching the
    jnp reference's scratch-column clamp.
    """
    TB, ns = kid.shape
    dstpos = emitted + rank                        # [TB, ns]
    cols = _iota((TB, ns, max_items), 2)
    onehot = emit[:, :, None] & (dstpos[:, :, None] == cols)
    hit = onehot.any(axis=1)                       # [TB, max_items]
    out_kid = jnp.where(
        hit, jnp.sum(jnp.where(onehot, kid[:, :, None], 0), axis=1), out_kid)
    out_val = jnp.where(
        hit, jnp.sum(jnp.where(onehot, val[:, :, None],
                               jnp.zeros((), out_val.dtype)), axis=1), out_val)
    emitted = jnp.minimum(
        emitted + jnp.sum(emit.astype(jnp.int32), axis=-1, keepdims=True),
        max_items)
    return out_kid, out_val, emitted


def _kernel(*refs, n_levels: int, fs: int, ns: int, L: int, max_items: int,
            collect_stats: bool):
    it = iter(refs)
    qb = next(it)[...]                        # [TB, L] u8
    ql = next(it)[...]                        # [TB, 1] i32
    knum_a = next(it)[...]                    # [n_levels, C]
    plen_a = next(it)[...]
    prefix_a = next(it)[...]                  # [n_levels, C, L]
    feats_a = next(it)[...]                   # [n_levels, C, fs, ns]
    child_a = next(it)[...]                   # [n_levels, C, ns]
    anch_a = next(it)[...]
    key_bytes = next(it)[...]                 # [KC, L] u8
    key_lens = next(it)[...][:, 0]            # [KC]
    leaf_high = next(it)[...][:, 0]           # [LC]
    leaf_next = next(it)[...][:, 0]
    leaf_keyid = next(it)[...]                # [LC, ns] i32
    leaf_val = next(it)[...]                  # [LC, ns]
    leaf_occ = next(it)[...]                  # [LC, ns] u8
    leaf_ordered = next(it)[...][:, 0]        # [LC] u8
    kid_ref = next(it)
    val_ref = next(it)
    emitted_ref = next(it)
    rearr_ref = next(it) if collect_stats else None

    TB = qb.shape[0]
    dump = leaf_next.shape[0] - 1             # scratch row = retired lane

    # ---------------- descent + sibling hop (shared with fused_descent) ---
    nid, _, _ = descend_levels(
        qb, ql, knum_a, plen_a, prefix_a, feats_a, child_a, anch_a,
        key_bytes, key_lens, n_levels=n_levels, fs=fs, ns=ns, L=L,
        collect_stats=False)
    nid, _ = sibling_hop(nid, qb, ql, key_bytes, key_lens,
                         leaf_high, leaf_next)

    out_kid = jnp.full((TB, max_items), -1, jnp.int32)
    out_val = jnp.zeros((TB, max_items), leaf_val.dtype)
    emitted = jnp.zeros((TB, 1), jnp.int32)

    def rows_at(cur):
        kid = jnp.take(leaf_keyid, cur, axis=0)           # [TB, ns]
        val = jnp.take(leaf_val, cur, axis=0)
        occ = jnp.take(leaf_occ, cur, axis=0) != 0
        return kid, val, occ

    def keys_at(kid, occ):
        kd = jnp.maximum(kid, 0).reshape(-1)
        kb = jnp.take(key_bytes, kd, axis=0).reshape(TB, ns, L)
        kl = jnp.where(occ, jnp.take(key_lens, kd).reshape(TB, ns), 0)
        return kb, kl

    # ---------------- hop 0 (peeled): in-kernel start-key compare ---------
    # the only hop that gathers key bytes unconditionally (the compare
    # needs them); the sort branch reuses the same gather
    cur = nid
    kid, val, occ = rows_at(cur)
    kb, kl = keys_at(kid, occ)
    dirty = jnp.take(leaf_ordered, cur) == 0
    emit = occ & (_cmp3_slots(kb, kl, qb, ql) >= 0)

    rank = jax.lax.cond(
        ~dirty.any(),
        lambda _: jnp.cumsum(emit.astype(jnp.int32), axis=-1) - 1,
        lambda _: _rank_among(kb, kl, emit),
        None)
    out_kid, out_val, emitted = _merge_emit(out_kid, out_val, emitted,
                                            kid, val, emit, rank, max_items)
    nxt = jnp.take(leaf_next, cur)
    cur = jnp.where((nxt >= 0) & (emitted[:, 0] < max_items), nxt, dump)
    rearr = dirty.astype(jnp.int32)[:, None] if collect_stats else None

    # ---------------- hops 1+: early-exit chain walk ----------------------
    # every key of an active leaf emits (ascending chain); the fast path
    # (all active leaves ordered) touches no key bytes at all
    def w_cond(c):
        return (c[0] != dump).any()

    def w_body(c):
        if collect_stats:
            cur, emitted, out_kid, out_val, rearr = c
        else:
            cur, emitted, out_kid, out_val = c
        active = cur != dump
        kid, val, occ = rows_at(cur)
        emit = occ & active[:, None]
        dirty = active & (jnp.take(leaf_ordered, cur) == 0)

        def _ordered(_):
            return jnp.cumsum(emit.astype(jnp.int32), axis=-1) - 1

        def _rearranged(_):
            kb, kl = keys_at(kid, occ)
            return _rank_among(kb, kl, emit)

        rank = jax.lax.cond(~dirty.any(), _ordered, _rearranged, None)
        out_kid, out_val, emitted = _merge_emit(
            out_kid, out_val, emitted, kid, val, emit, rank, max_items)
        nxt = jnp.take(leaf_next, cur)
        cur = jnp.where(active & (nxt >= 0) & (emitted[:, 0] < max_items),
                        nxt, dump)
        if collect_stats:
            return cur, emitted, out_kid, out_val, \
                rearr + dirty.astype(jnp.int32)[:, None]
        return cur, emitted, out_kid, out_val

    carry = (cur, emitted, out_kid, out_val)
    if collect_stats:
        carry = carry + (rearr,)
    final = jax.lax.while_loop(w_cond, w_body, carry)
    cur, emitted, out_kid, out_val = final[:4]

    kid_ref[...] = out_kid
    val_ref[...] = out_val
    emitted_ref[...] = emitted
    if collect_stats:
        rearr_ref[...] = final[4]


@functools.partial(
    jax.jit, static_argnames=("tile_b", "n_levels", "fs", "ns", "max_items",
                              "collect_stats", "interpret"))
def fused_scan_kernel(qb, ql, stacked_arrays, key_bytes, key_lens,
                      leaf_arrays, tile_b: int, n_levels: int, fs: int,
                      ns: int, max_items: int, collect_stats: bool,
                      interpret: bool = True):
    """One pallas_call for descent + sibling hop + leaf-chain range scan.

    ``stacked_arrays = (knum, plen, prefix, features, children, anchors)``
    stacked over levels; ``leaf_arrays = (high, next, keyid, val, occ_u8,
    ordered_u8)``. B must be a multiple of tile_b (ops.py pads). Queries
    tile over the grid; tree state rides as whole-array blocks
    (interpret-mode friendly; a real-TPU build would stream leaf blocks).
    """
    B, L = qb.shape
    assert B % tile_b == 0, (B, tile_b)
    grid = (B // tile_b,)

    tiled = lambda blk: pl.BlockSpec(
        blk, lambda i: (i,) + (0,) * (len(blk) - 1), memory_space=pltpu.VMEM)
    whole = lambda a: pl.BlockSpec(
        a.shape, lambda i, _nd=a.ndim: (0,) * _nd, memory_space=pltpu.VMEM)

    tree_state = list(stacked_arrays) + [key_bytes, key_lens] + list(leaf_arrays)
    inputs = [qb, ql] + tree_state
    in_specs = [tiled((tile_b, L)), tiled((tile_b, 1))]
    in_specs += [whole(a) for a in tree_state]

    val_dtype = leaf_arrays[3].dtype
    out_shape = [jax.ShapeDtypeStruct((B, max_items), jnp.int32),
                 jax.ShapeDtypeStruct((B, max_items), val_dtype),
                 jax.ShapeDtypeStruct((B, 1), jnp.int32)]
    out_specs = [tiled((tile_b, max_items)), tiled((tile_b, max_items)),
                 tiled((tile_b, 1))]
    if collect_stats:
        out_shape.append(jax.ShapeDtypeStruct((B, 1), jnp.int32))
        out_specs.append(tiled((tile_b, 1)))

    kern = functools.partial(_kernel, n_levels=n_levels, fs=fs, ns=ns, L=L,
                             max_items=max_items, collect_stats=collect_stats)
    return pl.pallas_call(
        kern, grid=grid, in_specs=in_specs, out_specs=out_specs,
        out_shape=out_shape, interpret=interpret)(*inputs)
