from .ops import fused_range_scan  # noqa: F401
