"""Pallas TPU flash-attention forward kernel (the LM-side hot spot).

Why a kernel: the XLA scan-based flash path (models/attention._sdpa_flash)
bounds *peak* memory but each (q-tile × kv-tile) logits block still
round-trips HBM (dot outputs materialize) — the dry-run's §Roofline shows
attention-tile traffic dominating the 32k-prefill memory term. Pallas keeps
the [block_q, block_k] tile in VMEM across the dot → online-softmax → dot
chain, so HBM traffic reduces to the q/k/v/out streams.

Grid: (batch×heads, n_q_blocks, n_kv_blocks) with kv innermost; the carry
(m, l, acc) lives in VMEM scratch across the kv sweep (standard
flash-attention-2 schedule on the MXU).

Masking: causal / window / prefix-LM computed from iotas per tile, same
MaskSpec semantics as the jnp paths. Padded kv positions are masked via the
`kv_len` scalar. Validated in interpret mode against ref.py
(= models.attention._sdpa_small oracle); on-TPU execution uses the same
BlockSpecs with interpret=False.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            scale: float, block_q: int, block_k: int, n_kv: int,
            kv_len: int, causal: bool, window: int, prefix_len: int,
            q_off_mult: int):
    """One (bh, iq, jk) grid step; kv (axis 2) is the innermost loop."""
    jk = pl.program_id(2)
    iq = pl.program_id(1)

    @pl.when(jk == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0]                                  # [block_q, hd]
    k = k_ref[0]                                  # [block_k, hd]
    v = v_ref[0]                                  # [block_k, hv]
    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * scale   # [bq, bk]

    qidx = iq * block_q * q_off_mult + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 0)
    kidx = jk * block_k + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 1)
    mask = kidx < kv_len
    if causal:
        cm = kidx <= qidx
        if window:
            cm &= kidx > qidx - window
        if prefix_len:
            cm |= kidx < prefix_len
        mask &= cm
    s = jnp.where(mask, s, NEG)

    m_prev = m_scr[...]                           # [bq, 1]
    m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
    p = jnp.where(mask, jnp.exp(s - m_new), 0.0)
    corr = jnp.exp(m_prev - m_new)
    l_scr[...] = l_scr[...] * corr + p.sum(axis=-1, keepdims=True)
    acc_scr[...] = acc_scr[...] * corr + jax.lax.dot_general(
        p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    m_scr[...] = m_new

    @pl.when(jk == n_kv - 1)
    def _finish():
        o_ref[0] = (acc_scr[...]
                    / jnp.maximum(l_scr[...], 1e-20)).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("scale", "block_q", "block_k", "causal",
                              "window", "prefix_len", "kv_len", "interpret"))
def flash_attention_kernel(q, k, v, *, scale: float, kv_len: int,
                           causal: bool = True, window: int = 0,
                           prefix_len: int = 0, block_q: int = 512,
                           block_k: int = 512, interpret: bool = True):
    """q [BH, S, hd], k/v [BH, T, hv] (heads pre-flattened, kv pre-repeated,
    S and T padded to block multiples). Returns [BH, S, hv]."""
    BH, S, hd = q.shape
    T = k.shape[1]
    hv = v.shape[-1]
    assert S % block_q == 0 and T % block_k == 0, (S, T, block_q, block_k)
    n_q, n_kv = S // block_q, T // block_k
    grid = (BH, n_q, n_kv)
    kern = functools.partial(
        _kernel, scale=scale, block_q=block_q, block_k=block_k, n_kv=n_kv,
        kv_len=kv_len, causal=causal, window=window, prefix_len=prefix_len,
        q_off_mult=1)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, hd), lambda b, i, j: (b, i, 0),
                         memory_space=pltpu.VMEM),
            pl.BlockSpec((1, block_k, hd), lambda b, i, j: (b, j, 0),
                         memory_space=pltpu.VMEM),
            pl.BlockSpec((1, block_k, hv), lambda b, i, j: (b, j, 0),
                         memory_space=pltpu.VMEM),
        ],
        out_specs=pl.BlockSpec((1, block_q, hv), lambda b, i, j: (b, i, 0),
                               memory_space=pltpu.VMEM),
        out_shape=jax.ShapeDtypeStruct((BH, S, hv), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, hv), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
