"""Jitted wrapper: GQA layout handling + custom-vjp backward (recompute).

``flash_sdpa`` is a drop-in for models.attention.sdpa: it flattens
(batch, kv-head, rep) onto one grid axis, repeats kv per group, pads S/T to
block multiples, and calls the Pallas kernel (interpret mode off-TPU).
Backward recomputes attention with the jnp flash path (standard
flash-attention recompute strategy — no tile residuals are stored).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.models.attention import MaskSpec, _sdpa_flash

from .kernel import flash_attention_kernel


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def flash_sdpa(q, k, v, mask: MaskSpec, n_rep: int, scale: float):
    """q [B,S,H,hd], k/v [B,T,Hk,hv] -> [B,S,H*hv]."""
    return _flash_fwd_impl(q, k, v, mask, n_rep, scale)


def _flash_fwd_impl(q, k, v, mask, n_rep, scale, block: int = 512):
    B, S, H, hd = q.shape
    T, Hk = k.shape[1], k.shape[2]
    hv = v.shape[-1]
    bq = min(block, max(128, S))
    bk = min(block, max(128, T))
    Sp = -(-S // bq) * bq
    Tp = -(-T // bk) * bk
    qf = jnp.pad(q, [(0, 0), (0, Sp - S), (0, 0), (0, 0)])
    kf = jnp.pad(k, [(0, 0), (0, Tp - T), (0, 0), (0, 0)])
    vf = jnp.pad(v, [(0, 0), (0, Tp - T), (0, 0), (0, 0)])
    # [B,S,Hk,rep,hd] -> [B*Hk*rep, S, hd]; kv repeated across rep
    qf = qf.reshape(B, Sp, Hk, n_rep, hd).transpose(0, 2, 3, 1, 4) \
           .reshape(B * Hk * n_rep, Sp, hd)
    kf = jnp.repeat(kf.transpose(0, 2, 1, 3)[:, :, None], n_rep, axis=2) \
           .reshape(B * Hk * n_rep, Tp, hd)
    vf = jnp.repeat(vf.transpose(0, 2, 1, 3)[:, :, None], n_rep, axis=2) \
           .reshape(B * Hk * n_rep, Tp, hv)
    out = flash_attention_kernel(
        qf, kf, vf, scale=scale, kv_len=T,
        causal=(mask.kind == "causal"), window=mask.window,
        prefix_len=mask.prefix_len, block_q=bq, block_k=bk,
        interpret=not _on_tpu())
    out = out.reshape(B, Hk, n_rep, Sp, hv).transpose(0, 3, 1, 2, 4)
    return out[:, :S].reshape(B, S, Hk * n_rep * hv)


def _fwd(q, k, v, mask, n_rep, scale):
    return _flash_fwd_impl(q, k, v, mask, n_rep, scale), (q, k, v)


def _bwd(mask, n_rep, scale, res, g):
    q, k, v = res
    # recompute-based backward through the jnp flash path (identical math)
    _, vjp = jax.vjp(
        lambda q_, k_, v_: _sdpa_flash(q_, k_, v_, mask, n_rep, scale),
        q, k, v)
    return vjp(g)


flash_sdpa.defvjp(_fwd, _bwd)
