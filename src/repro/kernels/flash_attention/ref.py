"""Pure-jnp oracle for the flash-attention kernel: the exact small-path
sdpa from models.attention (single materialized softmax)."""
from __future__ import annotations

import jax.numpy as jnp

from repro.models.attention import MaskSpec, _sdpa_small


def flash_attention_ref(q, k, v, *, scale: float, kv_len: int,
                        causal: bool = True, window: int = 0,
                        prefix_len: int = 0):
    """q [BH,S,hd], k/v [BH,T,hv] (kv already head-repeated). -> [BH,S,hv]."""
    BH, S, hd = q.shape
    T = k.shape[1]
    spec = MaskSpec("causal" if causal else "full", window, prefix_len)
    kmask = jnp.arange(T) < kv_len
    # fold kv-length masking into key padding with -inf via a huge negative
    # position trick: easiest is slicing since kv_len is static here
    qq = q[:, :, None, :]          # [BH, S, 1, hd]
    kk = k[:, :kv_len][:, :, None, :]
    vv = v[:, :kv_len][:, :, None, :]
    out = _sdpa_small(qq, kk, vv, spec, 1, scale=scale)
    out = out.reshape(BH, S, v.shape[-1])
    # rows with NO valid key (e.g. window entirely beyond kv_len) are
    # degenerate; the kernel's convention returns 0 for them — match it
    # (a bare softmax returns uniform weights over the -inf row instead)
    row_valid = spec.tile(jnp.arange(S), jnp.arange(kv_len)).any(-1)
    return jnp.where(row_valid[None, :, None], out, 0.0)
