"""Pure-jnp oracle for the leaf_probe kernel."""
from __future__ import annotations

import jax.numpy as jnp


def leaf_probe_ref(tags, occ, qtag):
    B, ns = tags.shape
    cand = (tags == qtag) & (occ != 0)
    lane = jnp.arange(ns, dtype=jnp.int32)[None, :]
    first = jnp.min(jnp.where(cand, lane, ns), axis=-1, keepdims=True)
    count = cand.sum(-1, keepdims=True).astype(jnp.int32)
    return cand.astype(jnp.uint8), first, count
