"""Pallas TPU kernel for hashtag leaf filtering (paper Fig. 6 lines 30-42).

``compare_equal(tags, tag) & bitmap`` over a whole lookup batch: one lane per
slot, candidates located with masked-iota reductions instead of TZCNT loops.
Exact key verification (line 37) needs data-dependent gathers from the key
pool and stays in XLA (see ops.py).

  tags [B, ns] u8, occ [B, ns] u8(0/1), qtag [B, 1] u8 ->
  cand [B, ns] u8 (mask), first [B, 1] i32 (ns if none), count [B, 1] i32
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_TILE_B = 512


def _kernel(tags_ref, occ_ref, qtag_ref, cand_ref, first_ref, count_ref, *,
            ns: int):
    tags = tags_ref[...]
    occ = occ_ref[...]
    qtag = qtag_ref[...]
    TB = tags.shape[0]
    cand = (tags == qtag) & (occ != 0)
    lane = jax.lax.broadcasted_iota(jnp.int32, (TB, ns), 1)
    first = jnp.min(jnp.where(cand, lane, ns), axis=-1, keepdims=True)
    count = jnp.sum(cand.astype(jnp.int32), axis=-1, keepdims=True)
    cand_ref[...] = cand.astype(jnp.uint8)
    first_ref[...] = first
    count_ref[...] = count


@functools.partial(jax.jit, static_argnames=("tile_b", "interpret"))
def leaf_probe_kernel(tags, occ, qtag, tile_b: int = DEFAULT_TILE_B,
                      interpret: bool = True):
    B, ns = tags.shape
    assert B % tile_b == 0
    vec = lambda blk: pl.BlockSpec(blk, lambda i: (i,) + (0,) * (len(blk) - 1),
                                   memory_space=pltpu.VMEM)
    return pl.pallas_call(
        functools.partial(_kernel, ns=ns),
        grid=(B // tile_b,),
        in_specs=[vec((tile_b, ns)), vec((tile_b, ns)), vec((tile_b, 1))],
        out_specs=[vec((tile_b, ns)), vec((tile_b, 1)), vec((tile_b, 1))],
        out_shape=[jax.ShapeDtypeStruct((B, ns), jnp.uint8),
                   jax.ShapeDtypeStruct((B, 1), jnp.int32),
                   jax.ShapeDtypeStruct((B, 1), jnp.int32)],
        interpret=interpret,
    )(tags, occ, qtag)
