"""Jitted wrapper: full leaf probe (tag filter kernel + exact verify in XLA)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.keys import fnv1a_tags
from repro.core.leaf import LeafStats

from .kernel import leaf_probe_kernel
from .ref import leaf_probe_ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def leaf_probe(tags, occ, qtag, use_pallas: bool = True, tile_b: int = 512):
    B = tags.shape[0]
    if not use_pallas:
        return leaf_probe_ref(tags, occ, qtag)
    Bp = -(-B // tile_b) * tile_b
    if Bp != B:
        tags = jnp.pad(tags, [(0, Bp - B), (0, 0)])
        occ = jnp.pad(occ, [(0, Bp - B), (0, 0)])
        qtag = jnp.pad(qtag, [(0, Bp - B), (0, 0)], constant_values=1)
    outs = leaf_probe_kernel(tags, occ.astype(jnp.uint8), qtag,
                             tile_b=tile_b, interpret=not _on_tpu())
    return tuple(o[:B] for o in outs)


def probe_pallas(tree, leaf_ids, qb, ql, use_pallas: bool = True):
    """Drop-in replacement for core.leaf.probe using the kernel for the
    hashtag filter; exact verification gathers only candidate slots."""
    a = tree.arrays
    ns = a.leaf_tags.shape[-1]
    qtag = fnv1a_tags(qb, ql)
    tags = a.leaf_tags[leaf_ids]
    occ = a.leaf_occ[leaf_ids]
    cand_u8, first, count = leaf_probe(tags, occ, qtag[:, None],
                                       use_pallas=use_pallas)
    cand = cand_u8 != 0
    kid = a.leaf_keyid[leaf_ids]
    kid_safe = jnp.maximum(kid, 0)
    akb = a.key_bytes[kid_safe]
    akl = a.key_lens[kid_safe]
    eqfull = (akb == qb[:, None, :]).all(-1) & (akl == ql[:, None]) & cand
    found = eqfull.any(-1)
    slot = jnp.argmax(eqfull, axis=-1).astype(jnp.int32)
    val = jnp.take_along_axis(a.leaf_val[leaf_ids], slot[:, None], axis=-1)[:, 0]
    val = jnp.where(found, val, 0)
    n_cand = count[:, 0]
    kw_lines = (ql + 63) // 64
    stats = LeafStats(
        tag_candidates=n_cand,
        lines_touched=(max(1, ns // 64) + 1 + n_cand * (1 + kw_lines)
                       ).astype(jnp.int32),
    )
    return found, slot, val, stats
