"""Jitted wrapper: full leaf probe (tag filter kernel + exact verify in XLA)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.keys import fnv1a_tags
from repro.core.leaf import LeafStats, verify_candidates

from .kernel import DEFAULT_TILE_B, leaf_probe_kernel
from ..feature_branch.kernel import auto_tile


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def leaf_probe(tags, occ, qtag, use_pallas: bool = True, tile_b: int = None):
    """``tile_b=None`` picks the largest power-of-two tile ≤ B (floor 8,
    cap ``DEFAULT_TILE_B``) so serving-sized batches stay pad-free."""
    B = tags.shape[0]
    if not use_pallas:
        from .ref import leaf_probe_ref
        return leaf_probe_ref(tags, occ, qtag)
    if tile_b is None:
        tile_b = auto_tile(B, DEFAULT_TILE_B)
    Bp = -(-B // tile_b) * tile_b
    if Bp != B:
        tags = jnp.pad(tags, [(0, Bp - B), (0, 0)])
        occ = jnp.pad(occ, [(0, Bp - B), (0, 0)])
        qtag = jnp.pad(qtag, [(0, Bp - B), (0, 0)], constant_values=1)
    outs = leaf_probe_kernel(tags, occ.astype(jnp.uint8), qtag,
                             tile_b=tile_b, interpret=not _on_tpu())
    return tuple(o[:B] for o in outs)


def probe_pallas(tree, leaf_ids, qb, ql, use_pallas: bool = True,
                 collect_stats: bool = True):
    """Drop-in replacement for core.leaf.probe using the kernel for the
    hashtag filter; exact verification gathers only candidate slots."""
    a = tree.arrays
    ns = a.leaf_tags.shape[-1]
    qtag = fnv1a_tags(qb, ql)
    tags = a.leaf_tags[leaf_ids]
    occ = a.leaf_occ[leaf_ids]
    cand_u8, first, count = leaf_probe(tags, occ, qtag[:, None],
                                       use_pallas=use_pallas)
    cand = cand_u8 != 0
    kid = a.leaf_keyid[leaf_ids]
    found, slot = verify_candidates(a, cand, kid, qb, ql)
    val = jnp.take_along_axis(a.leaf_val[leaf_ids], slot[:, None], axis=-1)[:, 0]
    val = jnp.where(found, val, 0)
    if not collect_stats:
        return found, slot, val, None
    n_cand = count[:, 0]
    kw_lines = (ql + 63) // 64
    stats = LeafStats(
        tag_candidates=n_cand,
        lines_touched=(max(1, ns // 64) + 1 + n_cand * (1 + kw_lines)
                       ).astype(jnp.int32),
    )
    return found, slot, val, stats
