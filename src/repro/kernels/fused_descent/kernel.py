"""Pallas TPU kernel: the WHOLE root→leaf descent in one launch.

The per-level engine (``kernels/feature_branch``) relaunches a kernel per
inner level and re-gathers node rows through XLA between launches; the
level-synchronous batched-descent designs (BS-tree, FPGA level-wise batch
search) show the win comes from keeping the descent resident. This kernel
tiles the *query batch* over the grid and loops the levels **inside** the
kernel body (unrolled — ``n_levels`` is static):

  per level-step: gather the tile's node rows (knum/plen/prefix/features/
  children/anchors) from the stacked ``[n_levels, C_max, ...]`` pytree into
  VMEM once, run the prefix compare + feature-comparison rounds (same
  masked-iota formulation as ``feature_branch``), then a suffix binary
  search clipped to the widest *surviving* equal run (a ``while_loop``, not
  a fixed ``ns.bit_length()`` unroll — lanes decided by prefix/feature/
  trivial nodes have their runs zeroed and cost nothing).

Epilogues, fused behind the same launch:
  * blink-style sibling hop (paper §4.3, bounded ``N_HOPS``);
  * the hashtag leaf probe (paper Fig. 6 lines 30-42) incl. full-key
    verification against the key pool — ``traverse_probe`` becomes ONE
    kernel launch instead of (n_levels + 1) launches plus XLA glue.

Static ``collect_stats`` drops every counter accumulator and stats output
from the compiled kernel; leaf ids / paths / probe results are bit-identical
either way (the parity suite pins this).

Tile sizing is ns-aware: per-tile VMEM scales with ``ns`` (feature rows,
anchor gathers, the [TB, ns, L] probe verify), so the tile cap halves from
256 at the paper's ns=64 to 128 at the TPU-natural ns=128
(:func:`descent_tile`).

Off-TPU this runs in interpret mode like every kernel in the repo; the tree
arrays ride in whole-array blocks, which interpret mode tolerates at any
size (a real-TPU deployment would stream level blocks per grid step).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..feature_branch.kernel import auto_tile, feature_compare_rounds

N_HOPS = 2          # bounded sibling hops; matches core.branch._SIBLING_HOPS
LANE_BUDGET = 32768  # tile_b * ns lanes held per gathered node-row block


def descent_tile(B: int, ns: int, floor: int = 8) -> int:
    """ns-aware tile: largest power of two ≤ B within the lane budget.

    ns=64 → cap 512, ns=128 → cap 256; a B=32 serving batch still gets a
    pad-free 32-row tile (the shared :func:`auto_tile` rule, with the cap
    derived from ``ns`` instead of a fixed default).
    """
    return auto_tile(B, max(floor, LANE_BUDGET // max(ns, 1)), floor)


def _iota(shape, dim):
    return jax.lax.broadcasted_iota(jnp.int32, shape, dim)


def _cmp3(ab, al, bb, bl):
    """3-way padded-key compare with length tie-break. [TB, L] × [TB, 1]."""
    TB, L = ab.shape
    diff = ab.astype(jnp.int32) - bb.astype(jnp.int32)
    nzm = diff != 0
    anynz = nzm.any(axis=-1, keepdims=True)
    pos = _iota((TB, L), 1)
    first_idx = jnp.min(jnp.where(nzm, pos, L), axis=-1, keepdims=True)
    first = jnp.take_along_axis(diff, jnp.minimum(first_idx, L - 1), axis=-1)
    return jnp.where(anynz, jnp.sign(first), jnp.sign(al - bl))


def _prefix_cmp(qb, prefix, plen):
    """First-diff compare of qb vs prefix over the first plen bytes."""
    TB, L = qb.shape
    pos = _iota((TB, L), 1)
    m = pos < plen
    diff = (qb.astype(jnp.int32) - prefix.astype(jnp.int32)) * m
    nzm = diff != 0
    anynz = nzm.any(axis=-1, keepdims=True)
    first_idx = jnp.min(jnp.where(nzm, pos, L), axis=-1, keepdims=True)
    first = jnp.take_along_axis(diff, jnp.minimum(first_idx, L - 1), axis=-1)
    return jnp.where(anynz, jnp.sign(first), 0)


def descend_levels(qb, ql, knum_a, plen_a, prefix_a, feats_a, child_a,
                   anch_a, key_bytes, key_lens, *, n_levels: int, fs: int,
                   ns: int, L: int, collect_stats: bool):
    """The in-kernel root→leaf descent over the stacked level arrays.

    SHARED between the fused descent kernel below and the fused range-scan
    kernel (``kernels/fused_scan``) — the parity contract (DESIGN.md §3)
    requires both to resolve bit-identical leaves, so there is exactly one
    definition of the level loop. Returns ``(nid, path_cols, stat_accs)``
    where ``stat_accs = (fr_acc, sb_acc, kc_acc, li_acc)`` (all-zero
    ``[TB, 1]`` columns when ``collect_stats`` is off — the accumulator
    arithmetic is never traced then).
    """
    TB = qb.shape[0]
    lines_per_row = max(1, ns // 64)
    kw_lines = (ql + 63) // 64                # [TB, 1]
    z = jnp.zeros((TB, 1), jnp.int32)
    fr_acc, sb_acc, kc_acc, li_acc = z, z, z, z

    nid = jnp.zeros((TB,), jnp.int32)         # root = node 0 of level 0
    path_cols = []

    for l in range(n_levels):
        path_cols.append(nid)
        kn = jnp.take(knum_a[l], nid)[:, None]            # [TB, 1]
        pl_ = jnp.take(plen_a[l], nid)[:, None]
        prefix = jnp.take(prefix_a[l], nid, axis=0)       # [TB, L]
        feats = jnp.take(feats_a[l], nid, axis=0)         # [TB, fs, ns]
        childs = jnp.take(child_a[l], nid, axis=0)        # [TB, ns]
        anch = jnp.take(anch_a[l], nid, axis=0)

        pcmp = _prefix_cmp(qb, prefix, pl_)               # [TB, 1]
        qpos = pl_ + _iota((TB, fs), 1)
        qfeat = jnp.take_along_axis(qb, jnp.clip(qpos, 0, L - 1), axis=-1)
        qfeat = jnp.where(qpos < L, qfeat, 0).astype(jnp.uint8)

        # shared with the per-level kernel — one definition of the
        # parity-critical compare loop
        idx, resolved, run_lo, run_hi, rounds = feature_compare_rounds(
            feats, qfeat, kn, pcmp, fs=fs, ns=ns,
            collect_stats=collect_stats)
        kmax = jnp.maximum(kn - 1, 0)
        trivial = kn <= 1
        need_bs = ~resolved                   # = billed: excl. pcmp/trivial

        # suffix binary search over the surviving run, width-bounded
        lo_b = jnp.where(need_bs, run_lo, 0)
        hi_b = jnp.where(need_bs, run_hi + 1, 0)

        def bs_cond(c):
            return (c[0] < c[1]).any()

        def bs_body(c, anch=anch):
            lo_b, hi_b, kc = c
            active = lo_b < hi_b
            mid = jnp.clip((lo_b + hi_b) // 2, 0, ns - 1)
            aid = jnp.take_along_axis(anch, mid, axis=-1)   # [TB, 1]
            aid_safe = jnp.maximum(aid[:, 0], 0)
            akb = jnp.take(key_bytes, aid_safe, axis=0)     # [TB, L]
            akl = jnp.take(key_lens, aid_safe)[:, None]
            c3 = _cmp3(akb, akl, qb, ql)                    # anchor vs query
            go_right = c3 <= 0
            lo_b = jnp.where(active & go_right, mid + 1, lo_b)
            hi_b = jnp.where(active & ~go_right, mid, hi_b)
            if collect_stats:
                kc = kc + active.astype(jnp.int32)
            return lo_b, hi_b, kc

        lo_b, _, key_cmp = jax.lax.while_loop(bs_cond, bs_body, (lo_b, hi_b, z))
        bs_idx = jnp.clip(lo_b - 1, 0, kmax)
        idx = jnp.where(need_bs, bs_idx, idx)
        child = jnp.take_along_axis(childs, idx, axis=-1)   # [TB, 1]
        nid = child[:, 0]

        if collect_stats:
            nz_ = lambda x: jnp.where(trivial, 0, x)
            fr = rounds                       # already trivial-zeroed
            kc = nz_(key_cmp)
            fr_acc = fr_acc + fr
            sb_acc = sb_acc + need_bs.astype(jnp.int32)
            kc_acc = kc_acc + kc
            li_acc = li_acc + nz_(1 + fr * lines_per_row
                                  + kc * (1 + kw_lines) + 1)

    return nid, path_cols, (fr_acc, sb_acc, kc_acc, li_acc)


def sibling_hop(nid, qb, ql, key_bytes, key_lens, leaf_high, leaf_next):
    """Blink-style sibling-hop epilogue (§4.3), ``N_HOPS``-bounded — shared
    with the fused range-scan kernel. Returns ``(nid, hops [TB, 1])``."""
    hops = jnp.zeros((qb.shape[0], 1), jnp.int32)
    for _ in range(N_HOPS):
        hk = jnp.take(leaf_high, nid)[:, None]              # [TB, 1]
        nxt = jnp.take(leaf_next, nid)[:, None]
        has_hk = hk >= 0
        hk_safe = jnp.maximum(hk[:, 0], 0)
        hkb = jnp.take(key_bytes, hk_safe, axis=0)
        hkl = jnp.take(key_lens, hk_safe)[:, None]
        c3 = _cmp3(qb, ql, hkb, hkl)                        # query vs high key
        must = has_hk & (c3 >= 0) & (nxt >= 0)
        nid = jnp.where(must[:, 0], nxt[:, 0], nid)
        hops = hops + must.astype(jnp.int32)
    return nid, hops


def _kernel(*refs, n_levels: int, fs: int, ns: int, L: int,
            sibling_check: bool, with_probe: bool, collect_stats: bool):
    it = iter(refs)
    qb = next(it)[...]                        # [TB, L] u8
    ql = next(it)[...]                        # [TB, 1] i32
    qtag = next(it)[...] if with_probe else None   # [TB, 1] u8
    knum_a = next(it)[...]                    # [n_levels, C]
    plen_a = next(it)[...]
    prefix_a = next(it)[...]                  # [n_levels, C, L]
    feats_a = next(it)[...]                   # [n_levels, C, fs, ns]
    child_a = next(it)[...]                   # [n_levels, C, ns]
    anch_a = next(it)[...]
    key_bytes = next(it)[...]                 # [KC, L] u8
    key_lens = next(it)[...][:, 0]            # [KC]
    if sibling_check:
        leaf_high = next(it)[...][:, 0]       # [LC]
        leaf_next = next(it)[...][:, 0]
    if with_probe:
        leaf_tags = next(it)[...]             # [LC, ns] u8
        leaf_occ = next(it)[...]              # [LC, ns] u8
        leaf_keyid = next(it)[...]            # [LC, ns] i32
        leaf_val = next(it)[...]              # [LC, ns]
    leaf_ref = next(it)
    path_ref = next(it)
    if with_probe:
        found_ref, slot_ref, val_ref = next(it), next(it), next(it)
    if collect_stats:
        fr_ref, sb_ref, kc_ref, li_ref, sh_ref = (
            next(it), next(it), next(it), next(it), next(it))
        tc_ref = next(it) if with_probe else None

    TB = qb.shape[0]
    lane = _iota((TB, ns), 1)
    z = jnp.zeros((TB, 1), jnp.int32)

    # ---------------- descent: all inner levels, resident in-kernel --------
    nid, path_cols, (fr_acc, sb_acc, kc_acc, li_acc) = descend_levels(
        qb, ql, knum_a, plen_a, prefix_a, feats_a, child_a, anch_a,
        key_bytes, key_lens, n_levels=n_levels, fs=fs, ns=ns, L=L,
        collect_stats=collect_stats)

    # ---------------- epilogue: blink-style sibling hop (§4.3) ------------
    hops = z
    if sibling_check:
        nid, hops = sibling_hop(nid, qb, ql, key_bytes, key_lens,
                                leaf_high, leaf_next)

    leaf_ref[...] = nid[:, None]
    path_ref[...] = jnp.stack(path_cols, axis=-1)           # [TB, n_levels]

    # ---------------- epilogue: hashtag leaf probe (Fig. 6 l.30-42) -------
    if with_probe:
        tags = jnp.take(leaf_tags, nid, axis=0)             # [TB, ns]
        occ = jnp.take(leaf_occ, nid, axis=0)
        cand = (tags == qtag) & (occ != 0)
        kid = jnp.take(leaf_keyid, nid, axis=0)
        # candidate-by-candidate verification (mirrors
        # core.leaf.verify_candidates): one [TB, L] key gather per round,
        # trip count = deepest candidate rank an unmatched lane needs
        crank = jnp.cumsum(cand.astype(jnp.int32), axis=-1) - 1
        n_cand = jnp.sum(cand.astype(jnp.int32), axis=-1, keepdims=True)

        def v_cond(c):
            checked, found, _ = c
            return ((~found) & (checked < n_cand)).any()

        def v_body(c):
            checked, found, slot = c
            active = (~found) & (checked < n_cand)
            is_k = cand & (crank == checked)
            s = jnp.min(jnp.where(is_k, lane, ns), axis=-1, keepdims=True)
            s = jnp.where(active, jnp.minimum(s, ns - 1), 0)
            kd = jnp.maximum(jnp.take_along_axis(kid, s, axis=-1)[:, 0], 0)
            akb = jnp.take(key_bytes, kd, axis=0)           # [TB, L]
            akl = jnp.take(key_lens, kd)[:, None]
            eqk = ((akb == qb).all(-1, keepdims=True) & (akl == ql)
                   & active)
            slot = jnp.where(eqk, s, slot)
            return checked + active.astype(jnp.int32), found | eqk, slot

        _, found, slot = jax.lax.while_loop(
            v_cond, v_body, (z, jnp.zeros((TB, 1), jnp.bool_), z))
        vals = jnp.take(leaf_val, nid, axis=0)
        val = jnp.take_along_axis(vals, slot, axis=-1)
        found_ref[...] = found.astype(jnp.int32)
        slot_ref[...] = slot
        val_ref[...] = jnp.where(found, val, 0)

    if collect_stats:
        fr_ref[...] = fr_acc
        sb_ref[...] = sb_acc
        kc_ref[...] = kc_acc
        li_ref[...] = li_acc
        sh_ref[...] = hops
        if with_probe:
            tc_ref[...] = jnp.sum(cand.astype(jnp.int32), axis=-1,
                                  keepdims=True)


@functools.partial(
    jax.jit, static_argnames=("tile_b", "n_levels", "fs", "ns",
                              "sibling_check", "with_probe", "collect_stats",
                              "interpret"))
def fused_descent_kernel(qb, ql, qtag, stacked_arrays, key_bytes, key_lens,
                         leaf_arrays, tile_b: int, n_levels: int, fs: int,
                         ns: int, sibling_check: bool, with_probe: bool,
                         collect_stats: bool, interpret: bool = True):
    """One pallas_call for descent (+ sibling hop + leaf probe).

    ``stacked_arrays = (knum, plen, prefix, features, children, anchors)``
    stacked over levels; ``leaf_arrays = (high, next)`` + ``(tags, occ_u8,
    keyid, val)`` when probing (pass ``()`` slices when a stage is off).
    B must be a multiple of tile_b (ops.py pads). Queries are tiled over the
    grid; tree state rides as whole-array blocks (interpret-mode friendly;
    a real-TPU build would stream per-level blocks).
    """
    B, L = qb.shape
    assert B % tile_b == 0, (B, tile_b)
    grid = (B // tile_b,)

    tiled = lambda blk: pl.BlockSpec(
        blk, lambda i: (i,) + (0,) * (len(blk) - 1), memory_space=pltpu.VMEM)
    whole = lambda a: pl.BlockSpec(
        a.shape, lambda i, _nd=a.ndim: (0,) * _nd, memory_space=pltpu.VMEM)

    inputs = [qb, ql]
    in_specs = [tiled((tile_b, L)), tiled((tile_b, 1))]
    if with_probe:
        inputs.append(qtag)
        in_specs.append(tiled((tile_b, 1)))
    tree_state = list(stacked_arrays) + [key_bytes, key_lens] + list(leaf_arrays)
    inputs += tree_state
    in_specs += [whole(a) for a in tree_state]

    out_shape = [jax.ShapeDtypeStruct((B, 1), jnp.int32),        # leaf
                 jax.ShapeDtypeStruct((B, n_levels), jnp.int32)]  # path
    out_specs = [tiled((tile_b, 1)), tiled((tile_b, n_levels))]
    if with_probe:
        val_dtype = leaf_arrays[-1].dtype
        out_shape += [jax.ShapeDtypeStruct((B, 1), jnp.int32),
                      jax.ShapeDtypeStruct((B, 1), jnp.int32),
                      jax.ShapeDtypeStruct((B, 1), val_dtype)]
        out_specs += [tiled((tile_b, 1))] * 3
    if collect_stats:
        n_stats = 6 if with_probe else 5
        out_shape += [jax.ShapeDtypeStruct((B, 1), jnp.int32)] * n_stats
        out_specs += [tiled((tile_b, 1))] * n_stats

    kern = functools.partial(_kernel, n_levels=n_levels, fs=fs, ns=ns, L=L,
                             sibling_check=sibling_check,
                             with_probe=with_probe,
                             collect_stats=collect_stats)
    return pl.pallas_call(
        kern, grid=grid, in_specs=in_specs, out_specs=out_specs,
        out_shape=out_shape, interpret=interpret)(*inputs)
