from .ops import fused_traverse, fused_traverse_probe  # noqa: F401
