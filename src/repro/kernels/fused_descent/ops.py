"""Engine-facing wrappers for the fused whole-descent kernel.

Registered as the ``"fused"`` descent backend in ``core.traverse``
(DESIGN.md §3): :func:`fused_traverse` matches the descent-backend
signature, :func:`fused_traverse_probe` is the fused traverse+probe entry
``core.batch_ops._traverse_probe`` collapses to — one kernel launch for
descent + sibling hop + hashtag leaf probe, with BranchStats/LeafStats
accounting bit-identical to the ``jnp`` oracle when ``collect_stats`` is on
and compiled out entirely when off.
"""
from __future__ import annotations

from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.branch import BranchStats
from repro.core.fbtree import FBTree
from repro.core.keys import fnv1a_tags
from repro.core.leaf import LeafStats

from .kernel import descent_tile, fused_descent_kernel


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _run(tree: FBTree, qb, ql, sibling_check: bool, with_probe: bool,
         collect_stats: bool):
    a = tree.arrays
    s = a.stacked
    n_levels = len(a.levels)
    fs = s.features.shape[-2]
    ns = s.features.shape[-1]
    B = qb.shape[0]

    tile_b = descent_tile(B, ns)
    Bp = -(-B // tile_b) * tile_b
    qb_p, ql_p = qb, ql
    qtag_p = None
    if with_probe:
        qtag_p = fnv1a_tags(qb, ql)[:, None]
    if Bp != B:
        qb_p = jnp.pad(qb, [(0, Bp - B), (0, 0)])
        ql_p = jnp.pad(ql, [(0, Bp - B)])
        if with_probe:
            qtag_p = jnp.pad(qtag_p, [(0, Bp - B), (0, 0)])

    stacked_arrays = (s.knum, s.plen, s.prefix, s.features, s.children,
                      s.anchors)
    leaf_arrays = ()
    if sibling_check:
        leaf_arrays += (a.leaf_high[:, None], a.leaf_next[:, None])
    if with_probe:
        leaf_arrays += (a.leaf_tags, a.leaf_occ.astype(jnp.uint8),
                        a.leaf_keyid, a.leaf_val)

    outs = fused_descent_kernel(
        qb_p, ql_p[:, None], qtag_p, stacked_arrays, a.key_bytes,
        a.key_lens[:, None], leaf_arrays, tile_b=tile_b, n_levels=n_levels,
        fs=fs, ns=ns, sibling_check=sibling_check, with_probe=with_probe,
        collect_stats=collect_stats, interpret=not _on_tpu())
    outs = [o[:B] for o in outs]

    it = iter(outs)
    leaf_ids = next(it)[:, 0]
    path_arr = next(it)
    path = [path_arr[:, l] for l in range(n_levels)]
    found = slot = val = None
    if with_probe:
        found = next(it)[:, 0].astype(bool)
        slot = next(it)[:, 0]
        val = next(it)[:, 0]
    bstats = lstats = None
    if collect_stats:
        fr, sb, kc, li, sh = (next(it)[:, 0] for _ in range(5))
        bstats = BranchStats(feat_rounds=fr, suffix_bs=sb, key_compares=kc,
                             lines_touched=li, sibling_hops=sh)
        if with_probe:
            tc = next(it)[:, 0]
            kw_lines = (ql + 63) // 64
            lstats = LeafStats(
                tag_candidates=tc,
                lines_touched=(max(1, ns // 64) + 1 + tc * (1 + kw_lines)
                               ).astype(jnp.int32))
    return leaf_ids, path, found, slot, val, bstats, lstats


def fused_traverse(tree: FBTree, qb, ql, sibling_check: bool = True,
                   collect_stats: bool = True,
                   ) -> Tuple[jnp.ndarray, List[jnp.ndarray],
                              Optional[BranchStats]]:
    """Descent-backend entry: whole root→leaf descent in one kernel launch.

    Returns ``(leaf_ids, path, stats | None)`` — the
    ``TraversalEngine.traverse`` contract.
    """
    leaf_ids, path, _, _, _, bstats, _ = _run(
        tree, qb, ql, sibling_check, with_probe=False,
        collect_stats=collect_stats)
    return leaf_ids, path, bstats


def fused_traverse_probe(tree: FBTree, qb, ql, sibling_check: bool = True,
                         collect_stats: bool = True):
    """Fused traverse+probe: descent, sibling hop, and the hashtag leaf
    probe (full-key verify included) in ONE launch. Returns
    ``(leaf_ids, path, found, slot, val, bstats | None, lstats | None)`` —
    the ``core.batch_ops._traverse_probe`` contract.
    """
    return _run(tree, qb, ql, sibling_check, with_probe=True,
                collect_stats=collect_stats)
