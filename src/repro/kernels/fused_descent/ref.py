"""jnp oracle for the fused descent: the same descend → sibling-hop → probe
pipeline composed from the core primitives (one XLA launch per stage instead
of one fused kernel). The parity suite pins the kernel against this via the
``jnp`` engine; this thin reference exists so kernel tests can compare
without importing the engine machinery.
"""
from __future__ import annotations

from repro.core.branch import branch_level, to_sibling
from repro.core.leaf import probe


def fused_traverse_ref(tree, qb, ql, sibling_check: bool = True,
                       collect_stats: bool = True):
    import jax.numpy as jnp
    from repro.core.branch import BranchStats
    a = tree.arrays
    B = qb.shape[0]
    node_ids = jnp.zeros((B,), jnp.int32)
    stats = BranchStats.zeros(B) if collect_stats else None
    path = []
    for level in a.levels:
        path.append(node_ids)
        node_ids, s = branch_level(level, a.key_bytes, a.key_lens, node_ids,
                                   qb, ql, collect_stats=collect_stats)
        if collect_stats:
            stats = stats + s
    if sibling_check:
        node_ids, hops = to_sibling(tree, node_ids, qb, ql)
        if collect_stats:
            stats = stats._replace(sibling_hops=stats.sibling_hops + hops)
    return node_ids, path, stats


def fused_traverse_probe_ref(tree, qb, ql, sibling_check: bool = True,
                             collect_stats: bool = True):
    leaf_ids, path, bstats = fused_traverse_ref(
        tree, qb, ql, sibling_check=sibling_check,
        collect_stats=collect_stats)
    found, slot, val, lstats = probe(tree, leaf_ids, qb, ql,
                                     collect_stats=collect_stats)
    return leaf_ids, path, found, slot, val, bstats, lstats
