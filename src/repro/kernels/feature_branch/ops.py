"""Jitted wrapper: full branch_level built on the feature_branch kernel.

Registered as the ``"pallas"`` backend in the traversal-engine registry
(``core.traverse``) — drop-in for core.branch.branch_level with identical
BranchStats accounting. The gather / prefix-compare / suffix-binary-search
stages run in XLA, the feature-comparison hot loop in Pallas (interpret
mode off-TPU).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.branch import BranchStats, _first_diff_cmp
from repro.core.keys import compare_padded

from .kernel import feature_branch_kernel
from .ref import feature_branch_ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def feature_branch(feats, qfeat, knum, pcmp, use_pallas: bool = True,
                   tile_b: int = 256):
    """Pad-to-tile wrapper around the kernel (or the jnp oracle)."""
    B = feats.shape[0]
    if not use_pallas:
        return feature_branch_ref(feats, qfeat, knum, pcmp)
    Bp = -(-B // tile_b) * tile_b
    if Bp != B:
        padw = [(0, Bp - B)] + [(0, 0)] * (feats.ndim - 1)
        feats = jnp.pad(feats, padw)
        qfeat = jnp.pad(qfeat, [(0, Bp - B), (0, 0)])
        knum = jnp.pad(knum, [(0, Bp - B), (0, 0)])
        pcmp = jnp.pad(pcmp, [(0, Bp - B), (0, 0)])
    outs = feature_branch_kernel(feats, qfeat, knum, pcmp, tile_b=tile_b,
                                 interpret=not _on_tpu())
    return tuple(o[:B] for o in outs)


def branch_level_pallas(level, key_bytes, key_lens, node_ids, qb, ql,
                        use_pallas: bool = True):
    """Drop-in replacement for core.branch.branch_level using the kernel."""
    B = node_ids.shape[0]
    ns = level.features.shape[-1]
    fs = level.features.shape[-2]
    L = qb.shape[-1]
    lines_per_row = max(1, ns // 64)

    knum = level.knum[node_ids]
    plen = level.plen[node_ids]
    prefix = level.prefix[node_ids]
    feats = level.features[node_ids]

    pcmp = _first_diff_cmp(qb, prefix, plen)
    # query feature bytes following the per-node prefix
    qpos = plen[:, None] + jnp.arange(fs, dtype=jnp.int32)[None, :]
    qfeat = jnp.take_along_axis(qb, jnp.clip(qpos, 0, L - 1), axis=-1)
    qfeat = jnp.where(qpos < L, qfeat, 0).astype(jnp.uint8)

    idx1, resolved, run_lo, run_hi, rounds = feature_branch(
        feats, qfeat, knum[:, None], pcmp[:, None], use_pallas=use_pallas)
    idx = idx1[:, 0]
    resolved = resolved[:, 0].astype(bool)
    lo, hi = run_lo[:, 0], run_hi[:, 0]
    feat_rounds = rounds[:, 0]

    # suffix binary search fallback (XLA: data-dependent gathers)
    need_bs = ~resolved
    lo_b, hi_b = lo, hi + 1
    anchors = level.anchors[node_ids]
    key_cmp = jnp.zeros((B,), jnp.int32)
    for _ in range(max(1, ns.bit_length())):
        active = lo_b < hi_b
        mid = jnp.clip((lo_b + hi_b) // 2, 0, ns - 1)
        aid = jnp.take_along_axis(anchors, mid[:, None], axis=-1)[:, 0]
        aid_safe = jnp.maximum(aid, 0)
        c = compare_padded(key_bytes[aid_safe], key_lens[aid_safe], qb, ql)
        go_right = c <= 0
        lo_b = jnp.where(active & go_right, mid + 1, lo_b)
        hi_b = jnp.where(active & ~go_right, mid, hi_b)
        key_cmp = key_cmp + (active & need_bs).astype(jnp.int32)
    bs_idx = jnp.clip(lo_b - 1, 0, jnp.maximum(knum - 1, 0))
    idx = jnp.where(need_bs, bs_idx, idx)

    child = jnp.take_along_axis(level.children[node_ids], idx[:, None],
                                axis=-1)[:, 0]
    trivial = knum <= 1
    nz = lambda x: jnp.where(trivial, 0, x).astype(jnp.int32)
    kw_lines = (ql + 63) // 64
    stats = BranchStats(
        feat_rounds=nz(feat_rounds),
        suffix_bs=nz(need_bs.astype(jnp.int32)),
        key_compares=nz(key_cmp),
        lines_touched=nz(1 + feat_rounds * lines_per_row
                         + key_cmp * (1 + kw_lines) + 1),
        sibling_hops=jnp.zeros((B,), jnp.int32),
    )
    return child, stats
