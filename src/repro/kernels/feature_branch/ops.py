"""Jitted wrapper: full branch_level built on the feature_branch kernel.

Registered as the ``"pallas"`` backend in the traversal-engine registry
(``core.traverse``) — drop-in for core.branch.branch_level with identical
BranchStats accounting (and the same static ``collect_stats`` switch). The
gather / prefix-compare stages run in XLA, the feature-comparison hot loop
in Pallas (interpret mode off-TPU), and the suffix fallback shares
``core.branch.suffix_binary_search``: a while-loop bounded by the widest
surviving equal run, so levels where no lane needs the fallback (e.g.
single-child chain levels, knum <= 1 everywhere) skip the anchor-gather
compare rounds entirely instead of burning ``ns.bit_length()`` dead rounds.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.branch import (BranchStats, _first_diff_cmp,
                               suffix_binary_search)

from .kernel import DEFAULT_TILE_B, auto_tile, feature_branch_kernel
from .ref import feature_branch_ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def feature_branch(feats, qfeat, knum, pcmp, use_pallas: bool = True,
                   tile_b: int = None, collect_stats: bool = True):
    """Pad-to-tile wrapper around the kernel (or the jnp oracle).

    ``tile_b=None`` picks the largest power-of-two tile ≤ B (floor 8, cap
    ``DEFAULT_TILE_B``): a serving-sized batch is not padded to the
    throughput tile.
    """
    B = feats.shape[0]
    if not use_pallas:
        return feature_branch_ref(feats, qfeat, knum, pcmp)
    if tile_b is None:
        tile_b = auto_tile(B, DEFAULT_TILE_B)
    Bp = -(-B // tile_b) * tile_b
    if Bp != B:
        padw = [(0, Bp - B)] + [(0, 0)] * (feats.ndim - 1)
        feats = jnp.pad(feats, padw)
        qfeat = jnp.pad(qfeat, [(0, Bp - B), (0, 0)])
        knum = jnp.pad(knum, [(0, Bp - B), (0, 0)])
        pcmp = jnp.pad(pcmp, [(0, Bp - B), (0, 0)])
    outs = feature_branch_kernel(feats, qfeat, knum, pcmp, tile_b=tile_b,
                                 interpret=not _on_tpu(),
                                 collect_stats=collect_stats)
    return tuple(o[:B] for o in outs)


def branch_level_pallas(level, key_bytes, key_lens, node_ids, qb, ql,
                        use_pallas: bool = True, collect_stats: bool = True):
    """Drop-in replacement for core.branch.branch_level using the kernel."""
    B = node_ids.shape[0]
    ns = level.features.shape[-1]
    fs = level.features.shape[-2]
    L = qb.shape[-1]
    lines_per_row = max(1, ns // 64)

    knum = level.knum[node_ids]
    plen = level.plen[node_ids]
    prefix = level.prefix[node_ids]
    feats = level.features[node_ids]

    pcmp = _first_diff_cmp(qb, prefix, plen)
    # query feature bytes following the per-node prefix
    qpos = plen[:, None] + jnp.arange(fs, dtype=jnp.int32)[None, :]
    qfeat = jnp.take_along_axis(qb, jnp.clip(qpos, 0, L - 1), axis=-1)
    qfeat = jnp.where(qpos < L, qfeat, 0).astype(jnp.uint8)

    outs = feature_branch(feats, qfeat, knum[:, None], pcmp[:, None],
                          use_pallas=use_pallas, collect_stats=collect_stats)
    idx1, resolved, run_lo, run_hi = outs[:4]
    idx = idx1[:, 0]
    resolved = resolved[:, 0].astype(bool)
    lo, hi = run_lo[:, 0], run_hi[:, 0]
    feat_rounds = outs[4][:, 0] if len(outs) > 4 else None

    # suffix binary search fallback (XLA: data-dependent gathers). The
    # kernel's `resolved` already folds in the prefix/trivial overrides, so
    # ~resolved is exactly the billed-fallback lane set of the jnp oracle.
    need_bs = ~resolved
    lo_b, key_cmp = suffix_binary_search(
        level.anchors, node_ids, key_bytes, key_lens, qb, ql, lo, hi,
        need_bs, ns, count_compares=collect_stats)
    bs_idx = jnp.clip(lo_b - 1, 0, jnp.maximum(knum - 1, 0))
    idx = jnp.where(need_bs, bs_idx, idx)

    child = level.children[node_ids, idx]
    if not collect_stats:
        return child, None
    trivial = knum <= 1
    nz = lambda x: jnp.where(trivial, 0, x).astype(jnp.int32)
    kw_lines = (ql + 63) // 64
    stats = BranchStats(
        feat_rounds=nz(feat_rounds),
        suffix_bs=nz(need_bs.astype(jnp.int32)),
        key_compares=nz(key_cmp),
        lines_touched=nz(1 + feat_rounds * lines_per_row
                         + key_cmp * (1 + kw_lines) + 1),
        sibling_hops=jnp.zeros((B,), jnp.int32),
    )
    return child, stats
