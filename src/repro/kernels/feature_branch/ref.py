"""Pure-jnp oracle for the feature_branch kernel (same math as core.branch)."""
from __future__ import annotations

import jax.numpy as jnp


def feature_branch_ref(feats, qfeat, knum, pcmp):
    """feats [B,fs,ns] u8, qfeat [B,fs] u8, knum/pcmp [B,1] i32 ->
    (idx, resolved, run_lo, run_hi, rounds), each [B,1] int32."""
    B, fs, ns = feats.shape
    lane = jnp.arange(ns, dtype=jnp.int32)[None, :]
    valid = lane < knum
    eq = valid
    resolved = jnp.zeros((B, 1), bool)
    idx = jnp.zeros((B, 1), jnp.int32)
    rounds = jnp.zeros((B, 1), jnp.int32)
    kmax = jnp.maximum(knum - 1, 0)
    for fid in range(fs):
        qb = qfeat[:, fid:fid + 1]
        frow = feats[:, fid, :]
        m = (frow == qb) & eq
        none_eq = ~m.any(-1, keepdims=True)
        less = (frow < qb) & eq
        lo = jnp.min(jnp.where(eq, lane, ns), axis=-1, keepdims=True)
        cnt_less = less.sum(-1, keepdims=True).astype(jnp.int32)
        res_idx = jnp.clip(lo + cnt_less - 1, 0, kmax)
        newly = none_eq & ~resolved
        idx = jnp.where(newly, res_idx, idx)
        rounds = rounds + (~resolved).astype(jnp.int32)
        resolved = resolved | none_eq
        eq = jnp.where(resolved, eq, m)
    run_lo = jnp.min(jnp.where(eq, lane, ns), axis=-1, keepdims=True)
    run_hi = jnp.max(jnp.where(eq, lane, -1), axis=-1, keepdims=True)
    idx = jnp.where(pcmp < 0, 0, idx)
    idx = jnp.where(pcmp > 0, kmax, idx)
    resolved = resolved | (pcmp != 0)
    trivial = knum <= 1
    idx = jnp.where(trivial, 0, idx)
    resolved = resolved | trivial
    rounds = jnp.where(trivial, 0, rounds)
    return (idx, resolved.astype(jnp.int32), run_lo, run_hi, rounds)
