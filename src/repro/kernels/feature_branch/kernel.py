"""Pallas TPU kernel for FB+-tree feature comparison (paper Fig. 6 lines 7-19).

Hardware adaptation (DESIGN.md §2): AVX-512 compares 64 anchor bytes per
instruction with 64-bit scalar mask registers; the TPU VPU operates on
(sublane, lane) = (8, 128) vector tiles. We therefore keep per-anchor masks
*vectorized* over the lane dimension (one lane per anchor) and replace the
paper's LZCNT/TZCNT bit tricks (`index_least1`, `countl_zero`) with masked
iota min/max reductions — cheaper than any cross-lane bit packing on TPU.
The natural TPU node size is ns=128 (one full lane row); ns=64 (the paper's
AVX-512 choice) half-fills lanes and is supported for faithfulness.

Inputs are per-query gathered node rows (the gather runs in XLA, which on TPU
lowers to efficient dynamic-slice streams; the kernel owns the compare/reduce
hot loop):
  feats [B, fs, ns] uint8   transposed feature rows
  qfeat [B, fs]     uint8   query bytes following each node's common prefix
  knum  [B, 1]      int32   anchors per node
  pcmp  [B, 1]      int32   3-way prefix compare result

Outputs:
  idx      [B, 1] int32  resolved child index (valid where resolved)
  resolved [B, 1] int32  1 = branch decided without suffix binary search
  run_lo/run_hi [B,1]    surviving equal-run bounds for the fallback search
  rounds   [B, 1] int32  feature rows consumed (paper-comparable counter) —
                         omitted when ``collect_stats=False`` (the stats-free
                         hot path compiles without the counter accumulator)
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_TILE_B = 256


def auto_tile(B: int, cap: int, floor: int = 8) -> int:
    """Largest power-of-two tile ≤ min(B, cap), floored at ``floor``.

    A B=32 serving batch gets tile_b=32 (pad-free) instead of being padded
    to the 256/512 throughput tile; odd batches pad only to the next tile
    boundary below ``cap``.
    """
    t = floor
    while t * 2 <= min(B, cap):
        t *= 2
    return t


def feature_compare_rounds(feats, qfeat, knum, pcmp, *, fs: int, ns: int,
                           collect_stats: bool):
    """The in-kernel feature-comparison round loop (paper Fig. 6 l.7-19),
    [TB, 1]-keepdims masked-iota formulation. SHARED between the per-level
    kernel below and the fused whole-descent kernel
    (``kernels/fused_descent``) — the parity contract requires both to be
    bit-identical, so there is exactly one definition.

    Returns ``(idx, resolved, run_lo, run_hi, rounds)``; the prefix/trivial
    overrides are folded in (``resolved`` includes ``pcmp != 0`` and
    ``knum <= 1``), so ``~resolved`` is exactly the billed suffix-fallback
    lane set and ``rounds`` is already zeroed on trivial nodes. ``rounds``
    stays all-zero (and costs nothing) when ``collect_stats`` is off.
    """
    TB = feats.shape[0]
    lane = jax.lax.broadcasted_iota(jnp.int32, (TB, ns), 1)
    valid = lane < knum                         # [TB, ns]
    eq = valid
    resolved = jnp.zeros((TB, 1), jnp.bool_)
    idx = jnp.zeros((TB, 1), jnp.int32)
    rounds = jnp.zeros((TB, 1), jnp.int32)
    kmax = jnp.maximum(knum - 1, 0)

    for fid in range(fs):                       # unrolled: fs is 2..8
        qb = qfeat[:, fid:fid + 1]              # [TB, 1] uint8
        frow = feats[:, fid, :]                 # [TB, ns] uint8
        m = (frow == qb) & eq
        none_eq = ~m.any(axis=-1, keepdims=True)
        less = (frow < qb) & eq
        lo = jnp.min(jnp.where(eq, lane, ns), axis=-1, keepdims=True)
        cnt_less = jnp.sum(less.astype(jnp.int32), axis=-1, keepdims=True)
        res_idx = jnp.clip(lo + cnt_less - 1, 0, kmax)
        newly = none_eq & ~resolved
        idx = jnp.where(newly, res_idx, idx)
        if collect_stats:
            rounds = rounds + (~resolved).astype(jnp.int32)
        resolved = resolved | none_eq
        eq = jnp.where(resolved, eq, m)

    run_lo = jnp.min(jnp.where(eq, lane, ns), axis=-1, keepdims=True)
    run_hi = jnp.max(jnp.where(eq, lane, -1), axis=-1, keepdims=True)

    idx = jnp.where(pcmp < 0, 0, idx)
    idx = jnp.where(pcmp > 0, kmax, idx)
    resolved = resolved | (pcmp != 0)
    trivial = knum <= 1
    idx = jnp.where(trivial, 0, idx)
    resolved = resolved | trivial
    if collect_stats:
        rounds = jnp.where(trivial, 0, rounds)
    return idx, resolved, run_lo, run_hi, rounds


def _kernel(feats_ref, qfeat_ref, knum_ref, pcmp_ref, *out_refs, fs: int,
            ns: int, collect_stats: bool):
    idx, resolved, run_lo, run_hi, rounds = feature_compare_rounds(
        feats_ref[...], qfeat_ref[...], knum_ref[...], pcmp_ref[...],
        fs=fs, ns=ns, collect_stats=collect_stats)
    out_refs[0][...] = idx
    out_refs[1][...] = resolved.astype(jnp.int32)
    out_refs[2][...] = run_lo
    out_refs[3][...] = run_hi
    if collect_stats:
        out_refs[4][...] = rounds


@functools.partial(jax.jit,
                   static_argnames=("tile_b", "interpret", "collect_stats"))
def feature_branch_kernel(feats, qfeat, knum, pcmp, tile_b: int = DEFAULT_TILE_B,
                          interpret: bool = True, collect_stats: bool = True):
    """B must be a multiple of tile_b (ops.py pads). With
    ``collect_stats=False`` the rounds output (and its in-kernel
    accumulator) is dropped — 4 outputs instead of 5."""
    B, fs, ns = feats.shape
    assert B % tile_b == 0, (B, tile_b)
    grid = (B // tile_b,)
    n_out = 5 if collect_stats else 4
    out_sds = [jax.ShapeDtypeStruct((B, 1), jnp.int32)] * n_out
    kern = functools.partial(_kernel, fs=fs, ns=ns,
                             collect_stats=collect_stats)
    vec = lambda blk: pl.BlockSpec(blk, lambda i: (i,) + (0,) * (len(blk) - 1),
                                   memory_space=pltpu.VMEM)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[vec((tile_b, fs, ns)), vec((tile_b, fs)),
                  vec((tile_b, 1)), vec((tile_b, 1))],
        out_specs=[vec((tile_b, 1))] * n_out,
        out_shape=out_sds,
        interpret=interpret,
    )(feats, qfeat, knum, pcmp)
