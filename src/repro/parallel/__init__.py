from . import sharding, compression, pipeline  # noqa: F401
