"""GPipe-style pipeline parallelism via shard_map + collective_permute.

Opt-in: the production mesh is (pod, data, model); PP introduces a "stage"
axis for deployments where layer count × width exceeds TP+DP reach. The
schedule is the classic GPipe bubble: M microbatches flow through P stages;
each tick every stage computes its microbatch then ppermutes activations to
the next stage. Lowered in the dry-run to prove the collective program is
coherent (bubble fraction = (P-1)/(M+P-1), reported in §Roofline notes).
"""
from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

# jax >= 0.6 promotes shard_map to jax.shard_map (replication check renamed
# check_vma); on the 0.4/0.5 line it lives in jax.experimental as check_rep
if hasattr(jax, "shard_map"):
    _shard_map = jax.shard_map
    _CHECK_KW = "check_vma"
else:
    from jax.experimental.shard_map import shard_map as _shard_map
    _CHECK_KW = "check_rep"


def pipeline_apply(mesh: Mesh, stage_fn: Callable, params_stacked, x,
                   n_micro: int):
    """Run x [M*mb, ...] through P pipeline stages.

    params_stacked: pytree with leading dim P (one slice per stage).
    stage_fn(stage_params, x_mb) -> x_mb.
    """
    n_stages = mesh.shape["stage"]
    assert x.shape[0] % n_micro == 0
    mb = x.shape[0] // n_micro

    def per_stage(params_local, x_local):
        # params_local: stage slice [1, ...]; x_local: microbatches for stage0
        pl = jax.tree_util.tree_map(lambda a: a[0], params_local)
        sid = jax.lax.axis_index("stage")
        n_ticks = n_micro + n_stages - 1
        perm = [(i, (i + 1) % n_stages) for i in range(n_stages)]

        def tick(carry, t):
            buf, out = carry            # buf: current activation [mb, ...]
            mb_id = t - sid
            active = (mb_id >= 0) & (mb_id < n_micro)
            # stage 0 ingests microbatch t from x_local
            feed = jax.lax.dynamic_slice_in_dim(
                x_local, jnp.clip(t, 0, n_micro - 1) * mb, mb, axis=0)
            cur = jnp.where((sid == 0)[..., None], feed, buf) \
                if feed.ndim == 1 else jnp.where(sid == 0, feed, buf)
            y = stage_fn(pl, cur)
            y = jnp.where(active, y, cur)
            # last stage emits; others pass along the ring
            out = jax.lax.cond(
                (sid == n_stages - 1),
                lambda o: jax.lax.dynamic_update_slice_in_dim(
                    o, y, jnp.clip(mb_id, 0, n_micro - 1) * mb, axis=0),
                lambda o: o, out)
            nxt = jax.lax.ppermute(y, "stage", perm)
            return (nxt, out), None

        buf0 = jnp.zeros((mb,) + x_local.shape[1:], x_local.dtype)
        out0 = jnp.zeros_like(x_local)
        (_, out), _ = jax.lax.scan(tick, (buf0, out0),
                                   jnp.arange(n_ticks, dtype=jnp.int32))
        return out

    fn = _shard_map(
        per_stage, mesh=mesh,
        in_specs=(P("stage"), P()),       # params split by stage; x replicated
        out_specs=P(),
        **{_CHECK_KW: False})
    return fn(params_stacked, x)
