"""Gradient compression with error feedback (distributed-optimization trick).

int8 block-quantization of gradients with an f32 error-feedback accumulator:
  q = round(g_scaled); err' = g - dequant(q); next step adds err' back.
Used between microbatch accumulation and the optimizer update; on a real
multi-host deployment the int8 tensors are what crosses DCN between pods
(4x byte reduction on the 'pod' axis all-reduce). Error feedback keeps the
asymptotic convergence of uncompressed SGD/Adam (Karimireddy et al., 2019).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

BLOCK = 256


def quantize_int8(g: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Per-block symmetric int8. Returns (q int8, scale f32)."""
    flat = g.astype(jnp.float32).reshape(-1)
    pad = (-flat.shape[0]) % BLOCK
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, BLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=-1, keepdims=True) / 127.0
    q = jnp.clip(jnp.round(blocks / jnp.maximum(scale, 1e-12)),
                 -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q, scale, shape) -> jnp.ndarray:
    flat = (q.astype(jnp.float32) * scale).reshape(-1)
    n = 1
    for s in shape:
        n *= s
    return flat[:n].reshape(shape)


def compress_grads_ef(grads, error_fb):
    """Apply int8 quantization with error feedback to every leaf."""
    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_e = jax.tree_util.tree_leaves(error_fb)
    deqs, errs = [], []
    for g, e in zip(flat_g, flat_e):
        corrected = g.astype(jnp.float32) + e
        q, s = quantize_int8(corrected)
        deq = dequantize_int8(q, s, g.shape)
        deqs.append(deq.astype(g.dtype))
        errs.append(corrected - deq)
    return (jax.tree_util.tree_unflatten(treedef, deqs),
            jax.tree_util.tree_unflatten(treedef, errs))
