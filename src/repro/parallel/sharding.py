"""Sharding rules: logical activation kinds + name-based parameter specs.

Model code calls ``shard(x, kind)`` at block boundaries; outside a sharding
context that is the identity, inside it becomes
``jax.lax.with_sharding_constraint`` with the mesh's rule table. Parameter
specs are derived from tree paths + shapes with divisibility checks (a dim
is sharded over an axis only if the axis size divides it — otherwise GSPMD
padding waste is avoided by replicating; e.g. paligemma's 8 q-heads on a
16-way model axis stay replicated, its 16384 d_ff shards).

Axes: batch-like dims shard over ("pod","data") [present axes only], tensor
dims over "model" (Megatron TP / EP / vocab-parallel), optional FSDP adds
"data" on a weight dim (ZeRO-3-style; XLA inserts the all-gathers).
"""
from __future__ import annotations

import re
import threading
from typing import Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_CTX = threading.local()


def batch_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def axis_size(mesh: Mesh, *names: str) -> int:
    s = 1
    for n in names:
        if n in mesh.axis_names:
            s *= mesh.shape[n]
    return s


class ShardCtx:
    """Context manager installing activation-constraint rules for a mesh."""

    def __init__(self, mesh: Mesh, fsdp: bool = False):
        self.mesh = mesh
        self.fsdp = fsdp
        ba = batch_axes(mesh)
        self.rules = {
            "bsd": P(ba, None, None),
            "bsv": P(ba, None, "model"),
            "becd": P(ba, "model", None, None),
            "bsec": P(ba, None, "model", None),
            "bec": P(ba, "model", None),
            "bhst": P(ba, "model", None, None),
        }

    def __enter__(self):
        _CTX.ctx = self
        return self

    def __exit__(self, *a):
        _CTX.ctx = None


def shard(x, kind: str):
    ctx: Optional[ShardCtx] = getattr(_CTX, "ctx", None)
    if ctx is None:
        return x
    spec = ctx.rules.get(kind)
    if spec is None:
        return x
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(ctx.mesh, spec))


# ------------------------------------------------------------------- params
def _div(n: int, k: int) -> bool:
    return k > 0 and n % k == 0


def _path_str(path) -> str:
    out = []
    for p in path:
        if hasattr(p, "key"):
            out.append(str(p.key))
        elif hasattr(p, "name"):
            out.append(str(p.name))
        elif hasattr(p, "idx"):
            out.append(str(p.idx))
    return "/".join(out)


def param_spec(path: str, shape: Tuple[int, ...], mesh: Mesh,
               fsdp: bool = False, hd_shard: bool = False) -> P:
    """Name+shape-based parameter partition spec.

    Layer-stacked params carry 1-2 leading stack dims which are never
    sharded; we match on the *trailing* dims. ``hd_shard``: when the head
    count doesn't divide the model axis, shard the head_dim instead
    (decode-specialized: replicated QKV/O weights dominate decode HBM
    traffic; the price — partial-softmax all-reduces and a rotate-half
    permute — is tiny for single-token steps).
    """
    tp = axis_size(mesh, "model")
    dp = axis_size(mesh, "data")
    nd = len(shape)

    def spec_tail(*tail):
        return P(*([None] * (nd - len(tail)) + list(tail)))

    # ---- embeddings / heads: vocab-parallel (replicate if not divisible)
    if re.search(r"(^|/)embed$", path) or re.search(r"lm_head$", path):
        if path.endswith("lm_head"):          # [d, V]
            return spec_tail(None, "model" if _div(shape[-1], tp) else None)
        return spec_tail("model" if _div(shape[-2], tp) else None, None)
    # ---- MoE experts: EP over model, [E, d, f] / [E, f, d]
    if re.search(r"moe/(wi|wg|wo)$", path) or re.search(r"/mtp/.*moe/(wi|wg|wo)$", path):
        if _div(shape[-3] if nd >= 3 else 0, tp):
            return spec_tail("model", None, None)
        return spec_tail(None, None, None)
    if re.search(r"moe/router(_bias)?$", path):
        return P(*([None] * nd))
    # ---- attention projections [d, H, hd] / [H, hd, d] (+ biases [H, hd])
    if re.search(r"(attn|cross|self)/w[qkv]$", path):
        H, hd = shape[-2], shape[-1]
        if _div(H, tp):
            return spec_tail(None, "model", None)
        if hd_shard and _div(hd, tp):
            return spec_tail(None, None, "model")
        return spec_tail(None, None, None)
    if re.search(r"(attn|cross|self)/b[qkv]$", path):
        H, hd = shape[-2], shape[-1]
        if _div(H, tp):
            return spec_tail("model", None)
        if hd_shard and _div(hd, tp):
            return spec_tail(None, "model")
        return spec_tail(None, None)
    if re.search(r"(attn|cross|self)/wo$", path):
        H, hd = shape[-3], shape[-2]          # [.., H, hd, d] uniformly
        if _div(H, tp):
            return spec_tail("model", None, None)
        if hd_shard and _div(hd, tp):
            return spec_tail(None, "model", None)
        return spec_tail(None, None, None)
    # ---- MLA
    if re.search(r"attn/wuq$", path) or re.search(r"attn/wukv$", path):
        H = shape[-2]
        return spec_tail(None, "model" if _div(H, tp) else None, None)
    if re.search(r"attn/(wdq|wdkv|wkr)$", path):
        return spec_tail(None, None)
    # ---- dense MLP [d, f] / [f, d]
    if re.search(r"mlp/(wi|wg)$", path) or re.search(r"shared/(wi|wg)$", path):
        return spec_tail(None, "model" if _div(shape[-1], tp) else None)
    if re.search(r"mlp/wo$", path) or re.search(r"shared/wo$", path):
        return spec_tail("model" if _div(shape[-2], tp) else None, None)
    # ---- mamba
    if re.search(r"mixer/in_proj$", path):
        return spec_tail(None, "model" if _div(shape[-1], tp) else None)
    if re.search(r"mixer/out_proj$", path):
        return spec_tail("model" if _div(shape[-2], tp) else None, None)
    if re.search(r"mixer/(x_proj|dt_w)$", path):
        return spec_tail("model" if _div(shape[-2], tp) else None, None)
    if re.search(r"mixer/(conv_w|conv_b|dt_b|A_log|D|norm_w)$", path):
        return P(*([None] * nd))
    # ---- projectors / positions / norms / everything else: replicated
    return P(*([None] * nd))


def _validate(spec: P, shape, mesh: Mesh) -> P:
    """Drop any spec entry whose axis size doesn't divide the dim (pjit
    input shardings require exact divisibility; GSPMD padding is only for
    constraints)."""
    entries = list(spec) + [None] * (len(shape) - len(spec))
    out = []
    for e, n in zip(entries, shape):
        if e is None:
            out.append(None)
            continue
        names = e if isinstance(e, tuple) else (e,)
        k = axis_size(mesh, *names)
        out.append(e if (k and n % k == 0) else None)
    return P(*out)


def add_fsdp(spec: P, shape, mesh: Mesh, axis: str = "data") -> P:
    """ZeRO-3/FSDP: additionally shard one free dim over the data axis.
    Stack dims (dim 0 of rank≥3 scan-stacked params) are skipped so
    per-layer slicing stays trivial; the biggest free divisible dim wins
    (XLA inserts the per-layer all-gather — classic FSDP)."""
    if axis not in mesh.axis_names:
        return spec
    k = mesh.shape[axis]
    if k <= 1:
        return spec
    entries = list(spec) + [None] * (len(shape) - len(spec))
    cands = sorted(range(len(shape)), key=lambda i: -shape[i])
    for i in cands:
        if i == 0 and len(shape) >= 3:
            continue
        if entries[i] is None and shape[i] % k == 0 and shape[i] >= k:
            entries[i] = axis
            return P(*entries)
    return P(*entries)


def param_shardings(params, mesh: Mesh, fsdp: bool = False,
                    hd_shard: bool = False):
    """Pytree of NamedShardings matching ``params`` (works on SDS trees)."""
    def one(path, leaf):
        shape = np.shape(leaf)
        spec = param_spec(_path_str(path), shape, mesh, fsdp,
                          hd_shard=hd_shard)
        spec = _validate(spec, shape, mesh)
        if fsdp:
            spec = add_fsdp(spec, shape, mesh)
        return NamedSharding(mesh, spec)
    return jax.tree_util.tree_map_with_path(one, params)


def batch_shardings(batch, mesh: Mesh):
    ba = batch_axes(mesh)
    def one(leaf):
        shape = np.shape(leaf)
        spec = P(*([ba] + [None] * (len(shape) - 1)))
        return NamedSharding(mesh, _validate(spec, shape, mesh))
    return jax.tree_util.tree_map(one, batch)


# --------------------------------------------------------------- kv caches
def cache_spec(path: str, shape: Tuple[int, ...], mesh: Mesh) -> P:
    """Decode-cache specs: batch over ("pod","data"); kv-heads over "model"
    when divisible, else the *sequence* dim shards over "model"
    (flash-decoding-style partial softmax — XLA inserts the combines)."""
    tp = axis_size(mesh, "model")
    ba = batch_axes(mesh)
    nd = len(shape)
    dpp = axis_size(mesh, *ba)
    # identify [.., B, S, kv, hd] attention caches by rank+name
    if re.search(r"(^|/)(k|v)$", path) and nd >= 4:
        B, S, KV = shape[-4], shape[-3], shape[-2]
        lead = [None] * (nd - 4)
        bspec = ba if _div(B, dpp) else None
        if _div(KV, tp):
            return P(*lead, bspec, None, "model", None)
        return P(*lead, bspec, "model" if _div(S, tp) else None, None, None)
    if re.search(r"(ckv|krope)$", path) and nd >= 3:   # MLA latent [L,B,S,r]
        B, S = shape[-3], shape[-2]
        lead = [None] * (nd - 3)
        bspec = ba if _div(B, dpp) else None
        return P(*lead, bspec, "model" if _div(S, tp) else None, None)
    if re.search(r"(conv|ssm)$", path) and nd >= 3:    # mamba states
        B = shape[-3] if nd >= 3 else 0
        # [.., B, C, K] conv / [.., B, d, s] or [.., B, nh, hp, s] ssm
        lead = [None] * (nd - 3)
        bspec = ba if _div(B, dpp) else None
        c = shape[-2]
        return P(*lead, bspec, "model" if _div(c, tp) else None, None)
    if re.search(r"cross_[kv]$", path) and nd >= 4:
        B, S, KV = shape[-4], shape[-3], shape[-2]
        lead = [None] * (nd - 4)
        bspec = ba if _div(B, dpp) else None
        if _div(KV, tp):
            return P(*lead, bspec, None, "model", None)
        return P(*lead, bspec, "model" if _div(S, tp) else None, None, None)
    return P(*([None] * nd))


def cache_shardings(cache, mesh: Mesh):
    def one(path, leaf):
        spec = cache_spec(_path_str(path), np.shape(leaf), mesh)
        return NamedSharding(mesh, _validate(spec, np.shape(leaf), mesh))
    return jax.tree_util.tree_map_with_path(one, cache)
