import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

Proves the distribution config is coherent without hardware: params,
optimizer state, batch and caches exist only as ShapeDtypeStructs; jit
lowers with the production shardings; ``compile()`` runs the full SPMD
partitioner + layout pipeline; memory/cost analyses feed §Dry-run and
§Roofline of EXPERIMENTS.md.

Usage:
  python -m repro.launch.dryrun --arch qwen3-14b --shape train_4k --mesh single
  python -m repro.launch.dryrun --arch all --shape all --mesh both --out out/
"""
import argparse
import dataclasses
import functools
import json
import time
import traceback
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs import (ARCH_IDS, SHAPES, applicable, get_config,
                           input_specs)
from repro.launch import hlo_analysis as H
from repro.launch.mesh import make_production_mesh
from repro.models import lm
from repro.models.config import ModelConfig
from repro.parallel import sharding as SH
from repro.train import optim as O
from repro.train.train_step import init_state, make_train_step

SDS = jax.ShapeDtypeStruct


def opt_for(cfg: ModelConfig) -> O.OptConfig:
    # the 671B fits 512 chips only with factored second moments
    total, _ = cfg.param_count()
    kind = "adafactor" if total > 100e9 else "adamw"
    return O.OptConfig(kind=kind)


def train_remat(cfg: ModelConfig) -> str:
    return "full"        # baseline policy; §Perf iterates on this


def model_flops(cfg: ModelConfig, shape: str) -> float:
    sp = SHAPES[shape]
    total, active = cfg.param_count()
    D = sp.seq_len * sp.global_batch
    if sp.kind == "train":
        return 6.0 * active * D
    if sp.kind == "prefill":
        return 2.0 * active * D
    return 2.0 * active * sp.global_batch      # one token per sequence


def _sds_tree(tree):
    return jax.tree_util.tree_map(
        lambda l: SDS(l.shape, l.dtype), tree)


def build_cell(cfg: ModelConfig, shape: str, mesh, fsdp: bool = False,
               n_micro: int = 1, hd_shard: bool = False):
    """Returns (fn, arg_sds, in_shardings, out_shardings)."""
    sp = SHAPES[shape]
    specs = input_specs(cfg, shape)
    ctx = SH.ShardCtx(mesh)
    shard = SH.shard

    if sp.kind == "train":
        ocfg = opt_for(cfg)
        if cfg.remat == "none":          # caller may have set a policy
            cfg = dataclasses.replace(cfg, remat=train_remat(cfg))
        state_sds = jax.eval_shape(
            lambda: init_state(cfg, ocfg, jax.random.PRNGKey(0)))
        pshard = SH.param_shardings(state_sds["params"], mesh, fsdp=fsdp)
        oshard = O.opt_state_shardings(state_sds["opt"], pshard, mesh)
        state_shardings = {"params": pshard, "opt": oshard}
        batch_sds = specs
        bshard = SH.batch_shardings(batch_sds, mesh)
        step = make_train_step(cfg, ocfg, shard=shard, n_micro=n_micro)
        fn = lambda state, batch: step(state, batch)
        metr_shard = None  # replicated outputs
        in_sh = (state_shardings, bshard)
        out_sh = (state_shardings, None)
        return fn, (state_sds, batch_sds), in_sh, out_sh, ctx

    params_sds = jax.eval_shape(
        lambda: lm.init_params(cfg, jax.random.PRNGKey(0)))
    pshard = SH.param_shardings(params_sds, mesh, fsdp=False,
                                hd_shard=hd_shard)
    if sp.kind == "prefill":
        batch_sds = specs
        bshard = SH.batch_shardings(batch_sds, mesh)
        cache_len = sp.seq_len + 128     # room to decode after prefill
        fn = lambda params, batch: lm.prefill(params, cfg, batch, cache_len,
                                              SH.shard)
        cache_sds = jax.eval_shape(
            lambda: lm.init_cache(cfg, sp.global_batch, cache_len))
        cshard = SH.cache_shardings(cache_sds, mesh)
        lshard = None
        return (fn, (params_sds, batch_sds), (pshard, bshard),
                (lshard, cshard), ctx)

    # decode: one token against a full cache
    B, S = sp.global_batch, sp.seq_len
    cache_sds = jax.eval_shape(lambda: lm.init_cache(cfg, B, S))
    cshard = SH.cache_shardings(cache_sds, mesh)
    tok_sds = specs["tokens"]
    pos_sds = specs["pos"]
    ba = SH.batch_axes(mesh)
    from jax.sharding import NamedSharding, PartitionSpec as P
    dpp = SH.axis_size(mesh, *ba)
    tshard = NamedSharding(mesh, P(ba if B % dpp == 0 else None))
    fn = lambda params, tok, pos, cache: lm.decode_step(
        params, cfg, tok, pos, cache, SH.shard)
    return (fn, (params_sds, tok_sds, pos_sds, cache_sds),
            (pshard, tshard, tshard, cshard), (None, cshard), ctx)


def optimized_profile(arch: str, shape: str) -> Dict:
    """The §Perf-winning settings per family (EXPERIMENTS.md):
    FSDP for all training; cumsum scan for mamba1; dots-remat for SSM
    (NOT for MoE — saves the one-hot einsum outputs); head-dim sharding
    for decode of non-divisible-head archs."""
    cfg = get_config(arch)
    kind = SHAPES[shape].kind
    prof: Dict = {}
    if kind == "train":
        prof["fsdp"] = True
        if cfg.family == "ssm" and cfg.ssm_version == 1:
            prof["ssm_scan"] = "cumsum"
        if cfg.family in ("ssm", "hybrid"):
            prof["remat"] = "dots"
    if kind == "decode" and cfg.n_heads % 16 != 0 and cfg.hd % 16 == 0:
        prof["hd_shard"] = True
    return prof


def run_cell(arch: str, shape: str, mesh_kind: str, fsdp: bool = False,
             n_micro: int = 1, moe_impl: Optional[str] = None,
             remat: Optional[str] = None, hd_shard: bool = False,
             ssm_scan: Optional[str] = None,
             dump_hlo: Optional[str] = None,
             profile: Optional[str] = None) -> Dict:
    if profile == "optimized":
        prof = optimized_profile(arch, shape)
        fsdp = prof.get("fsdp", fsdp)
        remat = prof.get("remat", remat)
        hd_shard = prof.get("hd_shard", hd_shard)
        ssm_scan = prof.get("ssm_scan", ssm_scan)
    cfg = get_config(arch)
    if moe_impl and cfg.n_experts:
        cfg = dataclasses.replace(cfg, moe_impl=moe_impl)
    if ssm_scan:
        cfg = dataclasses.replace(cfg, ssm_scan=ssm_scan)
    ok, why = applicable(cfg, shape)
    rec: Dict = {"arch": arch, "shape": shape, "mesh": mesh_kind,
                 "n_micro": n_micro, "moe_impl": cfg.moe_impl}
    if not ok:
        rec.update(status="skipped", reason=why)
        return rec
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    n_chips = mesh.devices.size
    if remat:
        cfg = dataclasses.replace(cfg, remat=remat)
    t0 = time.time()
    fn, args_sds, in_sh, out_sh, ctx = build_cell(cfg, shape, mesh,
                                                  fsdp=fsdp, n_micro=n_micro,
                                                  hd_shard=hd_shard)
    try:
        with mesh, ctx:
            jfn = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh)
            lowered = jfn.lower(*args_sds)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower
        ca = compiled.cost_analysis() or {}
        if isinstance(ca, (list, tuple)):   # older jax: one dict per program
            ca = ca[0] if ca else {}
        try:
            ma = compiled.memory_analysis()
            mem = {k: int(getattr(ma, k)) for k in (
                "argument_size_in_bytes", "output_size_in_bytes",
                "temp_size_in_bytes", "generated_code_size_in_bytes",
                "alias_size_in_bytes") if hasattr(ma, k)}
        except Exception:
            mem = {}
        hlo = compiled.as_text()
        if dump_hlo:
            with open(dump_hlo, "w") as f:
                f.write(hlo)
        st = H.analyze_hlo(hlo)            # loop-corrected static analysis
        flops_pd = float(st["flops"])
        bytes_pd = float(st["traffic_bytes"])
        colls = st["collectives"]
        wire_pd = sum(d["wire_bytes"] for d in colls.values())
        mf = model_flops(cfg, shape)
        roof = H.roofline(flops_pd, bytes_pd, wire_pd, mf, n_chips)
        roof["xla_cost_flops_pd_loop_once"] = float(ca.get("flops", 0.0))
        rec.update(
            status="ok", n_chips=n_chips,
            lower_s=round(t_lower, 1), compile_s=round(t_compile, 1),
            flops_per_device=flops_pd, bytes_per_device=bytes_pd,
            collectives={k: {kk: (int(vv) if kk == "count" else float(vv))
                             for kk, vv in v.items()}
                         for k, v in colls.items()},
            collective_wire_bytes_pd=wire_pd,
            top_traffic=st["top_traffic"][:8],
            top_flops=st["top_flops"][:6],
            memory_analysis=mem, roofline=roof,
            params_total=cfg.param_count()[0],
            params_active=cfg.param_count()[1],
        )
    except Exception as e:
        rec.update(status="error", error=f"{type(e).__name__}: {e}",
                   trace=traceback.format_exc()[-2000:])
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="single", choices=["single", "multi",
                                                         "both"])
    ap.add_argument("--out", default=None)
    ap.add_argument("--fsdp", type=int, default=0)
    ap.add_argument("--hd-shard", type=int, default=0)
    ap.add_argument("--n-micro", type=int, default=1)
    ap.add_argument("--moe-impl", default=None)
    ap.add_argument("--remat", default=None)
    ap.add_argument("--profile", default=None,
                    choices=[None, "baseline", "optimized"])
    args = ap.parse_args()
    archs = ARCH_IDS if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    recs = []
    for a in archs:
        for s in shapes:
            for m in meshes:
                r = run_cell(a, s, m, fsdp=bool(args.fsdp),
                             n_micro=args.n_micro, moe_impl=args.moe_impl,
                             remat=args.remat, hd_shard=bool(args.hd_shard),
                             profile=args.profile)
                recs.append(r)
                line = {k: v for k, v in r.items()
                        if k not in ("trace", "collectives", "top_traffic",
                                     "top_flops", "memory_analysis")}
                print(json.dumps(line), flush=True)
    if args.out:
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        with open(args.out, "w") as f:
            json.dump(recs, f, indent=1)


if __name__ == "__main__":
    main()
