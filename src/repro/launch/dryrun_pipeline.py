import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Pipeline-parallel dry-run: prove the GPipe shard_map/ppermute schedule
lowers and compiles at production scale (opt-in PP config).

Mesh: 4 pipeline stages × 128 chips; each stage applies a slice of a
dense-block stack over the microbatched activations.

  PYTHONPATH=src python -m repro.launch.dryrun_pipeline
"""
import json
import time

import jax
import jax.numpy as jnp

from repro.launch import hlo_analysis as H
from repro.parallel.pipeline import pipeline_apply


def main():
    n_stages = 4
    mesh = jax.make_mesh((n_stages, 128), ("stage", "repl"))
    d, ff, layers_per_stage = 4096, 16384, 8
    n_micro, mb, S = 8, 4, 1024

    def stage_fn(pl_params, x):
        def body(h, w):
            wi, wo = w
            return h + jnp.tanh(h @ wi) @ wo, None
        h, _ = jax.lax.scan(body, x, pl_params)
        return h

    params_sds = (jax.ShapeDtypeStruct(
        (n_stages, layers_per_stage, d, ff), jnp.bfloat16),
        jax.ShapeDtypeStruct(
        (n_stages, layers_per_stage, ff, d), jnp.bfloat16))
    x_sds = jax.ShapeDtypeStruct((n_micro * mb, S, d), jnp.bfloat16)

    def fn(wi, wo, x):
        return pipeline_apply(mesh, stage_fn, (wi, wo), x, n_micro=n_micro)

    t0 = time.time()
    with mesh:
        lowered = jax.jit(fn).lower(*params_sds, x_sds)
        compiled = lowered.compile()
    hlo = compiled.as_text()
    st = H.analyze_hlo(hlo)
    perm = H.count_hlo_ops(hlo, ("collective-permute",))
    bubble = (n_stages - 1) / (n_micro + n_stages - 1)
    print(json.dumps({
        "status": "ok", "stages": n_stages, "n_micro": n_micro,
        "compile_s": round(time.time() - t0, 1),
        "collective_permutes": perm["collective-permute"],
        "permute_wire_GB_pd": round(
            st["collectives"].get("collective-permute", {})
            .get("wire_bytes", 0) / 1e9, 2),
        "gpipe_bubble_fraction": round(bubble, 3),
    }))


if __name__ == "__main__":
    main()
