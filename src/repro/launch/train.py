"""End-to-end training driver: data pipeline → sharded train_step →
checkpoint/restart with watchdog + optional failure injection.

CPU-scale runs use reduced configs (--smoke) on a local mesh; the same loop
lowers unchanged on the production mesh (the dry-run proves that part).

  PYTHONPATH=src python -m repro.launch.train --arch yi-9b --smoke \
      --steps 50 --batch 8 --seq 128 --ckpt /tmp/ck --inject-failure 23
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.launch.mesh import make_local_mesh
from repro.models.config import ModelConfig
from repro.parallel import sharding as SH
from repro.train import checkpoint as CK
from repro.train import ft
from repro.train.data import DataConfig, TokenStream
from repro.train import optim as O
from repro.train.train_step import init_state, make_train_step


def train_loop(cfg: ModelConfig, steps: int, batch: int, seq: int,
               ckpt_dir=None, save_every: int = 50, lr: float = 1e-3,
               inject_failure=None, mesh=None, log_every: int = 10,
               seed: int = 0, n_micro: int = 1, compress: bool = False):
    ocfg = O.OptConfig(lr=lr, warmup=min(20, steps // 5 or 1),
                       total_steps=steps)
    mesh = mesh or make_local_mesh()
    ctx = SH.ShardCtx(mesh)
    data = TokenStream(DataConfig(vocab=cfg.vocab, global_batch=batch,
                                  seq_len=seq, seed=seed), cfg)
    step_fn = make_train_step(cfg, ocfg, shard=SH.shard, n_micro=n_micro,
                              compress=compress)
    watchdog = ft.Watchdog()
    plan = ft.FailurePlan({inject_failure: "worker-loss"}
                          if inject_failure is not None else {})
    losses = {}

    state_box = {}

    def make_runner(start_step: int):
        if ckpt_dir and CK.latest_step(ckpt_dir) is not None:
            template = jax.eval_shape(
                lambda: init_state(cfg, ocfg, jax.random.PRNGKey(seed)))
            state, _ = CK.restore(ckpt_dir, template)
        else:
            state = init_state(cfg, ocfg, jax.random.PRNGKey(seed))
        state_box["state"] = state
        with mesh, ctx:
            jstep = jax.jit(step_fn, donate_argnums=0)

        def run_one(step: int) -> float:
            plan.check(step)
            b = {k: jnp.asarray(v) for k, v in data.batch_at(step).items()}
            with mesh, ctx:
                state_box["state"], metrics = jstep(state_box["state"], b)
            loss = float(metrics["loss"])
            losses[step] = loss
            if step % log_every == 0:
                print(f"step {step:5d} loss {loss:.4f} "
                      f"lr {float(metrics['lr']):.2e} "
                      f"gnorm {float(metrics['grad_norm']):.2f}", flush=True)
            return loss
        return run_one

    def saver(step: int):
        if ckpt_dir:
            CK.save(ckpt_dir, step, state_box["state"], keep=3, async_=True)

    def restorer() -> int:
        if ckpt_dir:
            s = CK.latest_step(ckpt_dir)
            return s if s is not None else 0
        return 0

    log = ft.run_with_restarts(steps, make_runner, save_every, saver,
                               restorer, watchdog=watchdog)
    if ckpt_dir:
        CK.save(ckpt_dir, steps, state_box["state"], keep=3, async_=False)
    return {"losses": losses, "restarts": log["restarts"],
            "stragglers": watchdog.stragglers,
            "state": state_box["state"]}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--save-every", type=int, default=50)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--inject-failure", type=int, default=None)
    ap.add_argument("--n-micro", type=int, default=1)
    ap.add_argument("--compress", action="store_true")
    ap.add_argument("--vocab", type=int, default=None)
    args = ap.parse_args()
    cfg = get_config(args.arch, smoke=args.smoke)
    if args.vocab:
        cfg = dataclasses.replace(cfg, vocab=args.vocab)
    t0 = time.time()
    out = train_loop(cfg, args.steps, args.batch, args.seq,
                     ckpt_dir=args.ckpt, save_every=args.save_every,
                     lr=args.lr, inject_failure=args.inject_failure,
                     n_micro=args.n_micro, compress=args.compress)
    ls = sorted(out["losses"].items())
    first = np.mean([l for _, l in ls[:5]])
    last = np.mean([l for _, l in ls[-5:]])
    print(json.dumps({"first5_loss": round(float(first), 4),
                      "last5_loss": round(float(last), 4),
                      "restarts": len(out["restarts"]),
                      "wall_s": round(time.time() - t0, 1)}))


if __name__ == "__main__":
    main()
