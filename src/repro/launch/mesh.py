"""Production mesh builders (pure functions — importing never touches jax
device state; the dry-run sets XLA_FLAGS before any jax import)."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape, axes):
    return jax.make_mesh(tuple(shape), tuple(axes))


def make_local_mesh(axes=("data", "model")):
    """1-device mesh with production axis names (CPU tests/smokes)."""
    return jax.make_mesh((1,) * len(axes), tuple(axes))


def make_pipeline_mesh(n_stages: int = 4):
    return jax.make_mesh((n_stages,), ("stage",))
