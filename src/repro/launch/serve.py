"""Serving driver: batched requests through the Engine + FB+-tree prefix
cache. CPU-scale demo with reduced configs; serve_step's production-scale
lowering is exercised by the dry-run.

  PYTHONPATH=src python -m repro.launch.serve --arch yi-9b --smoke \
      --requests 24 --shared-prefix 48
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.models import lm
from repro.serving import Engine, ServeConfig


def make_requests(n: int, vocab: int, shared_prefix: int, plen: int,
                  n_families: int = 4, seed: int = 0):
    """Request mix with skewed shared prefixes (system prompts) — the
    paper's zipfian key distribution analogue."""
    rng = np.random.default_rng(seed)
    fams = [rng.integers(0, vocab, size=shared_prefix) for _ in
            range(n_families)]
    out = []
    for i in range(n):
        fam = fams[int(rng.zipf(1.5)) % n_families]
        tail = rng.integers(0, vocab, size=plen - shared_prefix)
        out.append(np.concatenate([fam, tail]).astype(np.int32))
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-9b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--prompt-len", type=int, default=96)
    ap.add_argument("--shared-prefix", type=int, default=64)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-batch", type=int, default=4)
    args = ap.parse_args()
    cfg = get_config(args.arch, smoke=True)
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    scfg = ServeConfig(max_batch=args.max_batch,
                       s_max=args.prompt_len + args.max_new + 8,
                       block_tokens=16, n_pages=512,
                       max_new_tokens=args.max_new)
    eng = Engine(cfg, params, scfg)
    reqs = make_requests(args.requests, cfg.vocab, args.shared_prefix,
                         args.prompt_len)
    t0 = time.time()
    done = eng.run(reqs)
    dt = time.time() - t0
    toks = sum(len(r.out) for r in done)
    print(json.dumps({
        "requests": len(done),
        "all_done": all(r.done for r in done),
        "new_tokens": toks,
        "tok_per_s": round(toks / dt, 1),
        "prefix_hit_rate": round(eng.prefix.hit_rate(), 3),
        "tree_stats": eng.prefix.stats,
        "decode_steps": eng.steps,
    }))


if __name__ == "__main__":
    main()
