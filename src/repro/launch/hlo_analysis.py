"""Post-SPMD HLO static analysis: loop-corrected FLOPs / HBM traffic /
collective bytes + roofline terms.

Why not just ``compiled.cost_analysis()``: XLA's HloCostAnalysis visits a
``while`` body ONCE — scan-over-layers models under-count by ~n_layers.
This module parses the optimized HLO text (computations, symbol tables,
``backend_config known_trip_count``), and aggregates

  flops    — dot/convolution MACs ×2 (elementwise excluded: <2% here)
  traffic  — Σ (operand + result bytes) of top-level ops in *control*
             computations (ENTRY / loop bodies); fusion internals excluded —
             a fusion's HBM traffic is its operands + outputs. An upper
             bound (no buffer-reuse modeling); CPU lowering also converts
             some bf16 compute to f32, so treat as conservative.
  collectives — per kind: count, payload bytes, ring-model wire bytes

with every quantity multiplied by its enclosing loops' trip counts.
Hardware constants (assignment): 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI.
"""
from __future__ import annotations

import json
import re
from typing import Dict, List, Optional, Tuple

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "token": 0, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "f8e4m3": 1, "f8e3m4": 1, "u1": 1, "s1": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COMP_HDR = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->\s*.+\s*\{\s*$")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*((?:\([^()]*\)|[\w\[\],{}\s/*]+?))\s*"
    r"([\w\-]+)\((.*)$")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CALL_ATTR = re.compile(r"(?:calls|body|condition|to_apply)=%([\w.\-]+)")
_GROUPS_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_CDIMS_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")

_NO_TRAFFIC = {
    "parameter", "get-tuple-element", "tuple", "bitcast", "constant",
    "after-all", "partition-id", "replica-id", "iota",
    "rng-get-and-update-state",
    # call-like ops: their bodies are accounted via recursion; carried
    # buffers alias in place
    "while", "conditional", "call",
}
# ops a TPU backend fuses into consumers — a fusion made ONLY of these is a
# layout/dtype transform whose output never hits HBM on the target (the CPU
# backend materializes f32 converts of bf16 weights; counting those would
# double every weight read)
_TRANSFORM_OPS = {
    "parameter", "constant", "convert", "bitcast", "reshape", "transpose",
    "copy", "dynamic-slice", "slice", "broadcast", "get-tuple-element",
    "tuple", "iota",
}
_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")


def _shape_dims(type_str: str) -> List[Tuple[str, List[int]]]:
    out = []
    for m in _SHAPE_RE.finditer(type_str):
        dt = m.group(1)
        if dt not in _DTYPE_BYTES:
            continue
        dims = [int(d) for d in m.group(2).split(",") if d]
        out.append((dt, dims))
    return out


def _type_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _shape_dims(type_str):
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dt]
    return total


class Op:
    __slots__ = ("name", "type_str", "opcode", "rest")

    def __init__(self, name, type_str, opcode, rest):
        self.name, self.type_str = name, type_str
        self.opcode, self.rest = opcode, rest


class Computation:
    def __init__(self, name: str, is_entry: bool):
        self.name = name
        self.is_entry = is_entry
        self.ops: List[Op] = []
        self.symbols: Dict[str, str] = {}
        self.root: Optional[Op] = None


def parse_module(hlo: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for line in hlo.splitlines():
        h = _COMP_HDR.match(line)
        if h:
            cur = Computation(h.group(2), bool(h.group(1)))
            comps[cur.name] = cur
            continue
        if cur is None:
            continue
        if line.strip() == "}":
            cur = None
            continue
        m = _OP_RE.match(line)
        if not m:
            continue
        op = Op(m.group(1), m.group(2).strip(), m.group(3), m.group(4))
        cur.ops.append(op)
        cur.symbols[op.name] = op.type_str
        if "ROOT " in line:
            cur.root = op
    return comps


def _update_bytes(op: Op, c: "Computation") -> Optional[int]:
    """For dynamic-update-slice / scatter: bytes of the update operand."""
    names = _OPERAND_RE.findall(op.rest.split("), ")[0])
    if len(names) >= 2:
        t = c.symbols.get(names[1])
        if t:
            return _type_bytes(t)
    return None


_PARAM_IDX_RE = re.compile(r"parameter\((\d+)\)")
_SLICERS = ("dynamic-slice", "gather", "slice")


def _fusion_param_effective_bytes(fc: "Computation") -> Dict[int, int]:
    """Per-parameter effective read bytes of a fused computation.

    A parameter consumed ONLY through slicing ops (dynamic-slice / gather /
    slice, possibly via bitcast/reshape/convert-of-slice chains) reads just
    the slices, not the whole buffer (the scan-xs / KV-cache access
    pattern). Returns {param_index: bytes}; params not in the map read their
    full size.
    """
    users: Dict[str, List[Op]] = {}
    param_idx: Dict[str, int] = {}
    for op in fc.ops:
        if op.opcode == "parameter":
            # op.rest is what follows "parameter(" — i.e. "<idx>)..."
            m = re.match(r"(\d+)\)", op.rest)
            if m:
                param_idx[op.name] = int(m.group(1))
        for om in _OPERAND_RE.finditer(op.rest):
            users.setdefault(om.group(1), []).append(op)
    out: Dict[int, int] = {}
    for pname, idx in param_idx.items():
        frontier = [pname]
        slice_bytes = 0
        ok = True
        seen = set()
        while frontier and ok:
            nm = frontier.pop()
            if nm in seen:
                continue
            seen.add(nm)
            for u in users.get(nm, []):
                if u.opcode in _SLICERS:
                    slice_bytes += _type_bytes(u.type_str)
                elif u.opcode in ("bitcast", "reshape", "transpose", "copy",
                                  "convert"):
                    frontier.append(u.name)
                elif u.opcode == "dynamic-update-slice":
                    # base buffer of an in-place update: aliased, not read
                    names = _OPERAND_RE.findall(u.rest)
                    if names and names[0] == nm:
                        continue
                    ok = False
                else:
                    ok = False
        if ok and slice_bytes >= 0:
            out[idx] = slice_bytes
    return out


def _group_size(rest: str, default: int = 2) -> int:
    g = _GROUPS_RE.search(rest)
    if g:
        items = [x for x in g.group(1).split(",") if x.strip() != ""]
        return max(len(items), 1)
    gi = _GROUPS_IOTA_RE.search(rest)
    if gi:
        return max(int(gi.group(2)), 1)
    return default


_META_RE = re.compile(r'op_name="([^"]*)"')


def _op_tag(op: Op) -> str:
    m = _META_RE.search(op.rest)
    if not m:
        return f"{op.opcode}:{op.type_str.split('{')[0][:40]}"
    name = m.group(1)
    # keep the source-level suffix (most informative path segment)
    parts = [p for p in name.split("/") if p and not p.startswith("jit(")]
    return "/".join(parts[-3:]) if parts else op.opcode


class Analysis:
    def __init__(self):
        self.flops = 0.0
        self.traffic = 0.0
        self.colls: Dict[str, Dict[str, float]] = {}
        self.by_tag: Dict[str, List[float]] = {}   # tag -> [traffic, flops]

    def tag(self, op: Op, traffic: float, flops: float):
        t = self.by_tag.setdefault(_op_tag(op), [0.0, 0.0])
        t[0] += traffic
        t[1] += flops

    def add(self, other: "Analysis", mult: float):
        self.flops += other.flops * mult
        self.traffic += other.traffic * mult
        for k, v in other.colls.items():
            d = self.colls.setdefault(
                k, {"count": 0.0, "bytes": 0.0, "wire_bytes": 0.0})
            for kk in d:
                d[kk] += v[kk] * mult
        for k, (tr, fl) in other.by_tag.items():
            t = self.by_tag.setdefault(k, [0.0, 0.0])
            t[0] += tr * mult
            t[1] += fl * mult


def analyze_hlo(hlo: str) -> Dict:
    comps = parse_module(hlo)
    _fusion_eff_cache: Dict[str, Dict[int, int]] = {}
    entry = next((c for c in comps.values() if c.is_entry), None)
    # mark control computations (reachable via while/cond/entry, not fusions)
    fused = set()
    for c in comps.values():
        for op in c.ops:
            if op.opcode == "fusion":
                m = re.search(r"calls=%([\w.\-]+)", op.rest)
                if m:
                    fused.add(m.group(1))

    memo: Dict[str, Analysis] = {}

    def analyze(name: str, control: bool) -> Analysis:
        key = name + ("|c" if control else "|f")
        if key in memo:
            return memo[key]
        a = Analysis()
        memo[key] = a
        c = comps.get(name)
        if c is None:
            return a
        for op in c.ops:
            oc = op.opcode
            out_bytes = _type_bytes(op.type_str)
            # ---- flops
            if oc in ("dot", "convolution"):
                cd = _CDIMS_RE.search(op.rest)
                k = 1
                if cd:
                    lhs_name = _OPERAND_RE.search(op.rest)
                    lhs_t = c.symbols.get(lhs_name.group(1), "") if lhs_name \
                        else ""
                    dims = _shape_dims(lhs_t)
                    if dims:
                        ldims = dims[0][1]
                        for i in [int(x) for x in cd.group(1).split(",") if x]:
                            if i < len(ldims):
                                k *= ldims[i]
                out_elems = 0
                for dt, dd in _shape_dims(op.type_str):
                    n = 1
                    for d in dd:
                        n *= d
                    out_elems += n
                a.flops += 2.0 * out_elems * k
                a.tag(op, 0.0, 2.0 * out_elems * k)
            # ---- collectives
            base = oc[:-6] if oc.endswith("-start") else oc
            if base in _COLLECTIVES:
                gsize = _group_size(op.rest)
                ring = (gsize - 1) / gsize
                nb = out_bytes
                if base == "all-reduce":
                    wire = 2 * ring * nb
                elif base in ("all-gather", "reduce-scatter", "all-to-all"):
                    wire = ring * nb
                else:
                    wire = nb
                d = a.colls.setdefault(
                    base, {"count": 0.0, "bytes": 0.0, "wire_bytes": 0.0})
                d["count"] += 1
                d["bytes"] += nb
                d["wire_bytes"] += wire
            # ---- traffic (control computations only); in-place and
            # slicing ops count the *moved* bytes, not whole buffers
            if control and oc not in _NO_TRAFFIC and not oc.endswith("-done"):
                if oc in ("dynamic-slice", "gather", "slice"):
                    tr = 2 * out_bytes                  # read slice + write
                elif oc in ("dynamic-update-slice", "scatter"):
                    ub = _update_bytes(op, c)
                    tr = 2 * (ub if ub is not None else out_bytes)
                elif oc == "fusion":
                    callee = re.search(r"calls=%([\w.\-]+)", op.rest)
                    fc = comps.get(callee.group(1)) if callee else None
                    _dus_ops = ("dynamic-update-slice", "scatter")
                    root_dus = False
                    dus_op = None
                    if fc is not None:
                        has_dus = [o for o in fc.ops if o.opcode in _dus_ops]
                        if has_dus and all(
                                o.opcode in _TRANSFORM_OPS
                                or o.opcode in _dus_ops for o in fc.ops):
                            root_dus = True      # in-place update fusion
                            dus_op = has_dus[0]
                        elif fc.root is not None and \
                                fc.root.opcode in _dus_ops:
                            root_dus = True
                            dus_op = fc.root
                    transform_only = (fc is not None and all(
                        o.opcode in _TRANSFORM_OPS for o in fc.ops))
                    eff = (_fusion_eff_cache.get(fc.name)
                           if fc is not None else None)
                    if fc is not None and eff is None:
                        eff = _fusion_param_effective_bytes(fc)
                        _fusion_eff_cache[fc.name] = eff
                    in_bytes, biggest = 0, 0
                    opnames = _OPERAND_RE.findall(
                        op.rest.split(", calls=")[0])
                    for i, onm in enumerate(opnames):
                        t = c.symbols.get(onm)
                        if not t:
                            continue
                        b = _type_bytes(t)
                        if eff is not None and i in eff:
                            b = min(b, eff[i])
                        in_bytes += b
                        biggest = max(biggest, b)
                    if root_dus:
                        ub = (_update_bytes(dus_op, fc)
                              if dus_op is not None else None)
                        tr = in_bytes + (ub or 0)
                    elif transform_only:
                        # dtype/layout-transform fusion: fuses into its
                        # consumer on TPU; count the source read only
                        tr = in_bytes
                    else:
                        tr = out_bytes + in_bytes
                else:
                    in_bytes = 0
                    for om in _OPERAND_RE.finditer(
                            op.rest.split(" calls=")[0].split(" body=")[0]):
                        t = c.symbols.get(om.group(1))
                        if t:
                            in_bytes += _type_bytes(t)
                    tr = out_bytes + in_bytes
                a.traffic += tr
                a.tag(op, tr, 0.0)
            # ---- calls
            if oc == "while":
                trip = 1
                tm = _TRIP_RE.search(op.rest)
                if tm:
                    trip = int(tm.group(1))
                for attr in ("body", "condition"):
                    cm = re.search(attr + r"=%([\w.\-]+)", op.rest)
                    if cm:
                        a.add(analyze(cm.group(1), control), trip)
            elif oc == "fusion":
                cm = re.search(r"calls=%([\w.\-]+)", op.rest)
                if cm:
                    a.add(analyze(cm.group(1), False), 1)
            elif oc == "conditional":
                for cm in re.finditer(r"%([\w.\-]+)", op.rest):
                    if cm.group(1) in comps and cm.group(1) not in fused:
                        a.add(analyze(cm.group(1), control), 1)
            elif oc in ("call", "async-start"):
                cm = re.search(r"to_apply=%([\w.\-]+)", op.rest)
                if cm:
                    a.add(analyze(cm.group(1), control), 1)
        return a

    if entry is None:
        return {"flops": 0.0, "traffic_bytes": 0.0, "collectives": {},
                "top_traffic": [], "top_flops": []}
    a = analyze(entry.name, True)
    top_t = sorted(a.by_tag.items(), key=lambda kv: -kv[1][0])[:20]
    top_f = sorted(a.by_tag.items(), key=lambda kv: -kv[1][1])[:20]
    return {"flops": a.flops, "traffic_bytes": a.traffic,
            "collectives": {k: dict(v) for k, v in a.colls.items()},
            "top_traffic": [(k, v[0]) for k, v in top_t],
            "top_flops": [(k, v[1]) for k, v in top_f]}


# ------------------------------------------------------------------ roofline
def roofline(flops_pd: float, bytes_pd: float, coll_wire_pd: float,
             model_flops_global: float, n_chips: int) -> Dict[str, float]:
    compute_s = flops_pd / PEAK_FLOPS
    memory_s = bytes_pd / HBM_BW
    coll_s = coll_wire_pd / ICI_BW
    dominant = max(("compute", compute_s), ("memory", memory_s),
                   ("collective", coll_s), key=lambda t: t[1])[0]
    step_s = max(compute_s, memory_s, coll_s)
    hlo_flops_global = flops_pd * n_chips
    return {
        "compute_s": compute_s,
        "memory_s": memory_s,
        "collective_s": coll_s,
        "dominant": dominant,
        "bound_step_s": step_s,
        "model_flops": model_flops_global,
        "hlo_flops_global": hlo_flops_global,
        "useful_flop_ratio": (model_flops_global / hlo_flops_global
                              if hlo_flops_global else 0.0),
        "mfu_bound": (model_flops_global / (n_chips * PEAK_FLOPS) / step_s
                      if step_s else 0.0),
    }


def count_hlo_ops(hlo_text: str, names: Tuple[str, ...]) -> Dict[str, int]:
    c = {n: 0 for n in names}
    for line in hlo_text.splitlines():
        for n in names:
            if f" {n}(" in line or f" {n}-start(" in line:
                c[n] += 1
    return c


# legacy shim (benchmarks import collective_stats)
def collective_stats(hlo_text: str) -> Dict[str, Dict[str, float]]:
    return analyze_hlo(hlo_text)["collectives"]
