"""KV page pool: bitmap allocator + refcounts (paper leaf-bitmap design).

Pages hold one token-block of per-layer KV (or SSM snapshot) in a host-side
store; shared prefixes share pages via refcounting. The free list is a
bitmap — allocation = find-first-zero ranks, exactly the leaf-slot discipline
FB+-tree leaves use (occupancy bitmap + slot install).
"""
from __future__ import annotations

from typing import List, Optional

import numpy as np


class PagePool:
    def __init__(self, n_pages: int):
        self.n_pages = n_pages
        self.used = np.zeros(n_pages, dtype=bool)
        self.refs = np.zeros(n_pages, dtype=np.int32)
        self.last_access = np.zeros(n_pages, dtype=np.int64)
        self.hits = np.zeros(n_pages, dtype=np.int64)
        self.clock = 0

    @property
    def n_free(self) -> int:
        return int((~self.used).sum())

    def alloc(self, n: int) -> Optional[np.ndarray]:
        free = np.nonzero(~self.used)[0]
        if free.size < n:
            return None
        ids = free[:n]
        self.used[ids] = True
        self.refs[ids] = 1
        self.clock += 1
        self.last_access[ids] = self.clock
        return ids.astype(np.int32)

    def retain(self, ids: np.ndarray):
        self.refs[ids] += 1
        self.clock += 1
        self.last_access[ids] = self.clock
        self.hits[ids] += 1

    def touch(self, ids: np.ndarray):
        """Record access (LRU stamp + hit count) without pinning."""
        self.clock += 1
        self.last_access[ids] = self.clock
        self.hits[ids] += 1

    def release(self, ids: np.ndarray):
        self.refs[ids] -= 1
        # pages stay resident (cache) until evicted; refs==0 means evictable

    def evictable(self) -> np.ndarray:
        return np.nonzero(self.used & (self.refs <= 0))[0]

    def lru_candidates(self, n: int) -> np.ndarray:
        ev = self.evictable()
        order = np.argsort(self.last_access[ev])
        return ev[order[:n]].astype(np.int32)

    def evict(self, ids: np.ndarray):
        self.used[ids] = False
        self.refs[ids] = 0
