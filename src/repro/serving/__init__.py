"""Serving layer: FB+-tree prefix cache, page pool, paged serving engine.

Stable public surface — import from here, not from the submodules:

    from repro.serving import PrefixCache, PagePool, Engine, ...
"""
from .engine import Engine, Request, ServeConfig
from .pages import PagePool
from .prefix_cache import PrefixCache, chain_keys

__all__ = [
    "PrefixCache", "chain_keys",
    "PagePool",
    "Engine", "Request", "ServeConfig",
]
