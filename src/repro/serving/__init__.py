from . import engine, pages, prefix_cache  # noqa: F401
