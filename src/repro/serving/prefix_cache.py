"""FB+-tree-backed prefix cache (RadixAttention-style KV reuse).

Keys: 16-byte chained block digests — ``key_i = H(key_{i-1} ‖ tokens_i)``
for token blocks of ``block_tokens`` — appended with the block index so
sibling blocks of one chain sort adjacently (range-scan friendly; YCSB-E
analogue is the eviction sweep). Values: page ids into a PagePool.

All cache operations are *batched tree ops* on the FB+-tree core:
  admit(requests)  -> one lookup_batch over every block of every request
  publish(blocks)  -> one insert_batch (latch-free bulk-synchronous commit)
  touch            -> update_batch on access stamps (the paper's latch-free
                      update path: value CAS, version untouched, readers
                      never restart)
  evict sweep      -> range_scan over the digest space (scan engine,
                      DESIGN.md §6: dispatches to the fused scan kernel
                      when the cache's engine backend registers one, else
                      the jnp chain walk; leaves the cache keeps ordered
                      ride the lazy-rearrangement fast path)
  compact          -> rebuild (device-side bulk build, DESIGN.md §5) run
                      as an atomic fsck-gated publish through
                      core.lifecycle.TreeVersionManager (DESIGN.md §8):
                      a failed barrier leaves the old tree serving
This is exactly the paper's skewed workload: shared system prompts ⇒ heavy
key-prefix skew ⇒ the tree behaves trie-like (feature comparison wins).

**Sharded mode** (``n_shards > 1``, DESIGN.md §7): the cache runs on a
``repro.shard.ShardedTree`` — digests are uniform, so evenly spaced
first-byte sentinels seed a balanced range partition and every op above
routes through the shard layer unchanged (same engine, same semantics);
``compact`` becomes ``rebalance`` (the cross-shard barrier).
"""
from __future__ import annotations

import hashlib
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import batch_ops as B
from repro.core import keys as K
from repro.core.faults import FaultPlan, RetryPolicy
from repro.core.fbtree import TreeConfig, bulk_build
from repro.core.lifecycle import PublishReport, TreeVersionManager
from repro.core.traverse import TraversalEngine

from .pages import PagePool

KEY_W = 20  # 16-byte digest + 4-byte block index


def _digest(prev: bytes, tokens: np.ndarray) -> bytes:
    return hashlib.blake2b(prev + tokens.tobytes(), digest_size=16).digest()


def chain_keys(tokens: np.ndarray, block_tokens: int) -> List[bytes]:
    """Block-chain digests for one request's full token prefix."""
    out = []
    prev = b"\x00" * 16
    n_blocks = len(tokens) // block_tokens
    for i in range(n_blocks):
        blk = np.asarray(tokens[i * block_tokens:(i + 1) * block_tokens],
                         dtype=np.int32)
        prev = _digest(prev, blk)
        out.append(prev + int(i).to_bytes(4, "big"))
    return out


class PrefixCache:
    def __init__(self, n_pages: int = 4096, block_tokens: int = 32,
                 max_keys: int = 1 << 16,
                 engine: Optional[TraversalEngine] = None,
                 compact_factor: float = 4.0, n_shards: int = 1,
                 faults: Optional[FaultPlan] = None,
                 retry: Optional[RetryPolicy] = None):
        self.block_tokens = block_tokens
        # serving never reads the modeled hardware counters, so the default
        # engine runs the stats-free hot path (DESIGN.md §3): leaf ids and
        # found-ness are bit-identical, the counter machinery compiles to
        # nothing. An explicit `engine` is honored as-is (pass
        # collect_stats=True to trace counters through the cache).
        self.engine = (engine if engine is not None
                       else TraversalEngine(collect_stats=False))
        self.pool = PagePool(n_pages)
        # auto-compact (device rebuild, DESIGN.md §5) once the tree holds
        # compact_factor× more leaves than a fresh build of the live keys
        # would; 0/None disables the trigger (compact() stays callable)
        self.compact_factor = compact_factor
        self.n_shards = int(n_shards)
        self.faults = faults
        self.retry = retry
        cfg = TreeConfig.plan(
            max_keys=max_keys, key_width=KEY_W,
            stacked=(engine is not None and engine.layout == "stacked"))
        if self.n_shards > 1:
            from repro import shard as SH
            self._shard = SH
            # one sentinel per shard, first bytes evenly spaced over the
            # (uniform) digest space — balanced routing without rebalancing
            seeds = [bytes([(256 * s) // self.n_shards]) +
                     b"\x00" * (KEY_W - 1) for s in range(self.n_shards)]
            ks = K.make_keyset(seeds, KEY_W)
            tree = SH.sharded_build(
                ks, np.full(self.n_shards, -1, np.int32), self.n_shards,
                cfg=cfg)
        else:
            self._shard = None
            seed = K.make_keyset([b"\x00" * KEY_W], KEY_W)  # sentinel root
            tree = bulk_build(cfg, seed, np.array([-1], np.int32))
        # all tree state lives behind the version manager (DESIGN.md §8):
        # in-place ops commit under the current version; compact() is an
        # atomic fsck-gated publish, so a failed barrier can never leave
        # the cache serving from a half-built tree
        self.lifecycle = TreeVersionManager(tree, faults=faults)
        self.stats = {"lookups": 0, "hits": 0, "inserts": 0, "evicts": 0,
                      "rebuilds": 0}

    # ---- tree-op adapters: one call site per op, sharded or not ----
    @property
    def tree(self):
        """The serving tree — always the current published version."""
        return self.lifecycle.current

    @property
    def _cfg(self) -> TreeConfig:
        return self.tree.config

    def _leaf_count(self) -> int:
        if self._shard is not None:
            return sum(int(t.arrays.leaf_count) for t in self.tree.shards)
        return int(self.tree.arrays.leaf_count)

    def _key_headroom_ok(self, n_new: int) -> bool:
        """Can the pool absorb ``n_new`` appends without a compact?
        Sharded mode is conservative: assumes the whole batch routes to the
        fullest shard."""
        if self._shard is not None:
            worst = max(int(t.arrays.key_count) for t in self.tree.shards)
            return worst + n_new <= self._cfg.key_cap
        return int(self.tree.arrays.key_count) + n_new <= self._cfg.key_cap

    def _lookup(self, kb, kl):
        if self._shard is not None:
            # degraded lanes (report.degraded) serve from the last-barrier
            # snapshot: possibly-stale hits beat refusing the request
            return self._shard.lookup_batch(self.tree, kb, kl,
                                            engine=self.engine,
                                            faults=self.faults,
                                            retry=self.retry)
        return B.lookup_batch(self.tree, kb, kl, engine=self.engine)

    def _insert(self, kb, kl, vals):
        if self._shard is not None:
            tree, rep, _ = self._shard.insert_batch(
                self.tree, kb, kl, vals, engine=self.engine,
                faults=self.faults, retry=self.retry)
        else:
            tree, rep, _ = B.insert_batch(self.tree, kb, kl, vals,
                                          engine=self.engine)
        self.lifecycle.commit(tree)
        return rep

    def _remove(self, kb, kl):
        if self._shard is not None:
            tree, rep = self._shard.remove_batch(self.tree, kb, kl,
                                                 engine=self.engine,
                                                 faults=self.faults,
                                                 retry=self.retry)
        else:
            tree, rep = B.remove_batch(self.tree, kb, kl,
                                       engine=self.engine)
        self.lifecycle.commit(tree)
        return rep

    def _scan(self, kb, kl, max_items):
        """-> (kid-or-gkid, val, emitted); kid resolution goes through
        :meth:`_kid_rows`."""
        if self._shard is not None:
            kid, val, em, _, failed = self._shard.range_scan(
                self.tree, kb, kl, max_items=max_items, engine=self.engine,
                faults=self.faults, retry=self.retry)
            # a failed lane's emissions are a correct ascending prefix —
            # the eviction sweep just sees fewer candidates this round
            return kid, val, em
        kid, val, em, _ = B.range_scan(self.tree, kb, kl,
                                       max_items=max_items,
                                       engine=self.engine)
        return kid, val, em

    def _kid_rows(self, kid):
        """Resolve scan-returned key ids to (bytes, lens)."""
        if self._shard is not None:
            return self.tree.key_rows(kid)
        kb = np.asarray(self.tree.arrays.key_bytes)[kid]
        kl = np.asarray(self.tree.arrays.key_lens)[kid]
        return kb, kl

    # ---------------------------------------------------------------- admit
    def match(self, requests: Sequence[np.ndarray]
              ) -> Tuple[List[int], List[List[int]]]:
        """For each request: longest cached block-prefix.

        Returns (hit_blocks per request, page ids per request) — resolved in
        ONE batched lookup over all blocks of all requests.
        """
        all_keys: List[bytes] = []
        spans = []
        for toks in requests:
            ks = chain_keys(np.asarray(toks, np.int32), self.block_tokens)
            spans.append((len(all_keys), len(ks)))
            all_keys.extend(ks)
        if not all_keys:
            return [0] * len(requests), [[] for _ in requests]
        ks = K.make_keyset(all_keys, KEY_W)
        vals, rep = self._lookup(ks.bytes, ks.lens)
        vals = np.asarray(vals)
        found = np.asarray(rep.found)
        self.stats["lookups"] += len(all_keys)
        hit_blocks, pages = [], []
        for (off, n) in spans:
            h = 0
            pg: List[int] = []
            for i in range(n):
                if not found[off + i]:
                    break
                h += 1
                pg.append(int(vals[off + i]))
            hit_blocks.append(h)
            pages.append(pg)
            self.stats["hits"] += h
        # touch pages (latch-free update analogue on access metadata);
        # cache-resident pages stay evictable — callers pin explicitly via
        # pool.retain if they hold pages across steps
        flat = np.asarray([p for pg in pages for p in pg], np.int64)
        if flat.size:
            self.pool.touch(flat.astype(np.int32))
        return hit_blocks, pages

    # -------------------------------------------------------------- publish
    def publish(self, tokens: np.ndarray, n_known_blocks: int
                ) -> Optional[np.ndarray]:
        """Register the blocks of a freshly prefilled request; returns the
        page ids assigned to the *new* blocks (None if pool exhausted)."""
        ks_all = chain_keys(np.asarray(tokens, np.int32), self.block_tokens)
        new = ks_all[n_known_blocks:]
        if not new:
            return np.zeros((0,), np.int32)
        # key-pool headroom guard: evicted digests tombstone leaf slots but
        # only a rebuild reclaims their pool rows, and steady churn can march
        # key_count to key_cap while the live set stays small — compact
        # before appending would overflow (DESIGN.md §5)
        if not self._key_headroom_ok(len(new)):
            rep = self.compact()
            if not rep.ok and not self._key_headroom_ok(len(new)):
                # the barrier aborted (fault/fsck) and the old pool is
                # still full: degrade to not admitting new blocks rather
                # than crashing the serving loop on the append overflow
                return None
        ids = self.pool.alloc(len(new))
        if ids is None:
            self._evict(len(new) * 2)
            ids = self.pool.alloc(len(new))
            if ids is None:
                return None
        ks = K.make_keyset(new, KEY_W)
        self._insert(ks.bytes, ks.lens, ids.astype(np.int32))
        self.pool.release(ids)       # cache-owned: evictable until pinned
        self.stats["inserts"] += len(new)
        return ids

    # ---------------------------------------------------------------- evict
    def _evict(self, n: int):
        victims = self.pool.lru_candidates(n)
        if victims.size == 0:
            return
        # removing by value requires key lookup; we keep a reverse map built
        # from a range scan over the digest space (the YCSB-E analogue).
        # self.engine selects the scan route (DESIGN.md §6) and is
        # stats-free by default, so the rearranged counter costs nothing
        start = K.make_keyset([b"\x00" * KEY_W], KEY_W)
        kid, val, emitted = self._scan(
            start.bytes, start.lens,
            max_items=min(4096, self._cfg.key_cap))
        kid, val = np.asarray(kid[0]), np.asarray(val[0])
        vict = set(victims.tolist())
        sel = [i for i in range(int(emitted[0]))
               if int(val[i]) in vict and kid[i] >= 0]
        if not sel:
            return
        kb, kl = self._kid_rows(kid[sel])
        self._remove(kb, kl)
        self.pool.evict(victims)
        self.stats["evicts"] += len(sel)
        # cheap necessary condition first (leaf_count is a scalar pull;
        # frag_factor costs a device reduction): need >= 1 leaves, so
        # frag >= cf requires leaf_count >= cf
        if (self.compact_factor
                and self._leaf_count() >= self.compact_factor
                and self.frag_factor >= self.compact_factor):
            self.compact()

    # --------------------------------------------------------------- compact
    @property
    def frag_factor(self) -> float:
        """Allocated leaves vs the minimum a fresh build would use.

        Grows as splits allocate leaves that later drain through eviction;
        can sit below 1 while in-place slot reuse keeps early leaves denser
        than the ``leaf_fill`` build target (no compaction needed then).
        """
        live = self.tree.n_keys_live
        need = max(1, -(-live // self._cfg.leaf_fill))
        if self._shard is not None:
            # a sharded build can never use fewer than one leaf per shard,
            # so floor `need` there — otherwise a small live set reads as
            # permanently fragmented and the evict-time trigger thrashes
            # (rebalance can't drop below n_shards leaves)
            need = max(need, self.tree.n_shards)
        return self._leaf_count() / need

    def compact(self) -> PublishReport:
        """Online rebuild (DESIGN.md §5) as an atomic publish (§8): drop
        eviction tombstones, re-pack the key pool, and rebuild all levels
        device-side — **off to the side**. The staged tree is structurally
        fsck'd and swapped in only on success; an abort, capacity error,
        or corruption mid-barrier leaves the current tree serving,
        bit-identical (the crash-unsafety regression test in
        ``tests/test_serving.py`` pins this). Sharded mode runs the
        cross-shard form — ``repro.shard.rebalance`` (DESIGN.md §7) —
        which also re-balances the partition and re-admits downed shards.

        A bulk-synchronous barrier between serving batches — cached page
        ids (the tree *values*) survive, but key ids/leaf ids/versions
        from before the barrier are invalidated, which is fine here:
        match() re-traverses from scratch every batch. Returns a
        ``core.lifecycle.PublishReport``; on success ``rep.aux`` is the
        build/rebalance report (both expose ``n_live``/``reclaimed``).
        """
        if self._shard is not None:
            rep = self.lifecycle.rebalance(label="compact")
        else:
            rep = self.lifecycle.rebuild(label="compact")
        if rep.ok:
            self.stats["rebuilds"] += 1
        return rep

    def hit_rate(self) -> float:
        lk = max(self.stats["lookups"], 1)
        return self.stats["hits"] / lk
