"""Batched serving engine: continuous batching + FB+-tree prefix cache.

Requests are admitted in waves; each wave's prompts are matched against the
prefix cache (one batched tree lookup), prefilled from the first miss block
(KV for hit blocks is gathered from the page store), then decoded step-wise
in a fixed-size continuous batch. Finished slots are refilled immediately.

The page store keeps per-block KV on host (numpy) — the CPU-scale analogue
of a paged-attention block pool; at fleet scale the same bookkeeping drives
device-resident pages (serve_step lowers independently in the dry-run).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.models import lm
from repro.models.config import ModelConfig

from repro.core.traverse import TraversalEngine

from .prefix_cache import PrefixCache


@dataclasses.dataclass
class ServeConfig:
    max_batch: int = 8
    s_max: int = 256
    block_tokens: int = 32
    n_pages: int = 1024
    max_new_tokens: int = 32
    # traversal engine for the prefix-cache tree (None -> core default)
    tree_backend: Optional[str] = None
    tree_layout: Optional[str] = None
    # prefix-cache tree shards (>1 routes through repro.shard, DESIGN.md §7)
    tree_shards: int = 1
    # fault-injection plan for the cache's lifecycle + shard dispatch
    # (core.faults.FaultPlan; None = fault-free serving) — chaos harness
    faults: Optional[object] = None


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray
    out: List[int] = dataclasses.field(default_factory=list)
    done: bool = False
    cached_blocks: int = 0
    t0: float = 0.0       # arrival stamp (perf_counter) — request-latency
    #                       histogram observes done-time minus this


class Engine:
    def __init__(self, cfg: ModelConfig, params, scfg: ServeConfig):
        assert cfg.family not in ("ssm", "hybrid", "encdec", "vlm"), \
            "engine demo covers decoder-only KV families"
        self.cfg, self.params, self.scfg = cfg, params, scfg
        self.cache = lm.init_cache(cfg, scfg.max_batch, scfg.s_max)
        self.pos = np.zeros(scfg.max_batch, np.int32)
        self.live: List[Optional[Request]] = [None] * scfg.max_batch
        # serving is throughput-only: run the tree stats-free (DESIGN.md §3)
        tree_engine = (TraversalEngine(scfg.tree_backend or "jnp",
                                       scfg.tree_layout, collect_stats=False)
                       if (scfg.tree_backend or scfg.tree_layout) else None)
        self.prefix = PrefixCache(scfg.n_pages, scfg.block_tokens,
                                  engine=tree_engine,
                                  n_shards=scfg.tree_shards,
                                  faults=scfg.faults)
        # host page store: [n_pages, L, 2, block, kv, hd]
        L = cfg.n_layers
        self.page_kv = np.zeros(
            (scfg.n_pages, L, 2, scfg.block_tokens, cfg.n_kv_heads, cfg.hd),
            np.float32)
        self._decode = jax.jit(
            lambda p, t, pos, c: lm.decode_step(p, cfg, t, pos, c))
        self._prefill = jax.jit(
            lambda p, toks: lm.prefill(p, cfg, {"tokens": toks}, scfg.s_max))
        self.steps = 0
        self._blocks_hit = 0     # prefix-cache blocks served from the store
        self._blocks_seen = 0    # prompt blocks offered to the cache

    # ------------------------------------------------------------- plumbing
    def _store_blocks(self, cache_np, slot: int, page_ids: np.ndarray,
                      first_block: int):
        bt = self.scfg.block_tokens
        k, v = cache_np        # [L, B, S, kv, hd] each
        for j, pid in enumerate(page_ids):
            b0 = (first_block + j) * bt
            self.page_kv[pid, :, 0] = k[:, slot, b0:b0 + bt]
            self.page_kv[pid, :, 1] = v[:, slot, b0:b0 + bt]

    def _load_blocks(self, slot: int, page_ids: Sequence[int]):
        bt = self.scfg.block_tokens
        k = np.array(self.cache.k)
        v = np.array(self.cache.v)
        for j, pid in enumerate(page_ids):
            k[:, slot, j * bt:(j + 1) * bt] = self.page_kv[pid, :, 0]
            v[:, slot, j * bt:(j + 1) * bt] = self.page_kv[pid, :, 1]
        import repro.models.attention as A
        self.cache = A.KVCache(jnp.asarray(k), jnp.asarray(v))

    # --------------------------------------------------------------- admit
    def admit(self, reqs: List[Request]):
        """Fill free slots; batched prefix match across the whole wave."""
        waves = [r for r in reqs][: self.live.count(None)]
        if not waves:
            return
        with obs.span("serve.admit", wave=len(waves)):
            self._admit_wave(waves)

    def _admit_wave(self, waves: List[Request]):
        with obs.span("serve.cache_lookup"):
            hit_blocks, pages = self.prefix.match([r.prompt for r in waves])
        if obs.enabled():
            bt = self.scfg.block_tokens
            self._blocks_hit += int(sum(hit_blocks))
            self._blocks_seen += int(sum(
                r.prompt.shape[0] // bt for r in waves))
            if self._blocks_seen:
                obs.gauge("serve.hit_rate").set(
                    self._blocks_hit / self._blocks_seen)
            obs.counter("serve.admitted").inc(len(waves))
        for r, hb, pg in zip(waves, hit_blocks, pages):
            slot = self.live.index(None)
            r.cached_blocks = hb
            # prefill the whole prompt for the engine cache (single call),
            # but only *new* blocks are published to the page store
            toks = jnp.asarray(r.prompt, jnp.int32)[None]
            with obs.span("serve.prefill", rid=r.rid):
                logits, c = self._prefill(self.params, toks)
            k = np.array(self.cache.k)
            v = np.array(self.cache.v)
            k[:, slot] = 0
            v[:, slot] = 0
            k[:, slot, :r.prompt.shape[0]] = np.asarray(c.k)[:, 0, :r.prompt.shape[0]]
            v[:, slot, :r.prompt.shape[0]] = np.asarray(c.v)[:, 0, :r.prompt.shape[0]]
            import repro.models.attention as A
            self.cache = A.KVCache(jnp.asarray(k), jnp.asarray(v))
            if pg:   # demonstrate reuse: overwrite hit blocks from the store
                self._load_blocks(slot, pg)
            new_ids = self.prefix.publish(r.prompt, hb)
            if new_ids is not None and new_ids.size:
                self._store_blocks((np.asarray(c.k), np.asarray(c.v)),
                                   0, new_ids, hb)
            self.pos[slot] = r.prompt.shape[0]
            nxt = int(np.argmax(np.asarray(logits)[0]))
            r.out.append(nxt)
            self.live[slot] = r

    # ---------------------------------------------------------------- step
    def step(self):
        """One decode step for every live slot (continuous batch)."""
        toks = np.zeros(self.scfg.max_batch, np.int32)
        for i, r in enumerate(self.live):
            if r is not None:
                toks[i] = r.out[-1]
        with obs.span("serve.decode"):
            logits, self.cache = self._decode(
                self.params, jnp.asarray(toks), jnp.asarray(self.pos),
                self.cache)
            nxt = np.asarray(jnp.argmax(logits, -1))
        for i, r in enumerate(self.live):
            if r is None:
                continue
            self.pos[i] += 1
            r.out.append(int(nxt[i]))
            if (len(r.out) >= self.scfg.max_new_tokens
                    or self.pos[i] + 1 >= self.scfg.s_max):
                r.done = True
                self.live[i] = None
                if obs.enabled():
                    obs.counter("serve.completed").inc()
                    if r.t0:
                        obs.histogram("serve.request_latency_s").observe(
                            time.perf_counter() - r.t0)
        self.steps += 1

    def run(self, requests: List[np.ndarray], max_steps: int = 10_000
            ) -> List[Request]:
        t0 = time.perf_counter()
        queue = [Request(i, np.asarray(p, np.int32), t0=t0) for i, p in
                 enumerate(requests)]
        pending = list(queue)
        while (pending or any(self.live)) and self.steps < max_steps:
            if pending and None in self.live:
                n_free = self.live.count(None)
                self.admit(pending[:n_free])
                pending = pending[n_free:]
            if any(r is not None for r in self.live):
                self.step()
        return queue
