"""Shared benchmark machinery: datasets (paper Table 2 analogues,
synthesized offline with fixed seeds), tree builders, timed batched runs.

Wall-clock numbers are CPU-backend *relative* measurements (this container
has no TPU); machine-independent counters (key compares, modeled cache
lines, suffix-fallback rates, conflict groups) carry the paper-comparable
claims — see EXPERIMENTS.md.
"""
from __future__ import annotations

import time
from typing import Callable, Dict, List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import batch_ops as B
from repro.core import keys as K
from repro.core.fbtree import FBTree, TreeConfig, bulk_build
from repro.core.traverse import TraversalEngine, available_backends  # noqa: F401

SYLL = ["an", "ber", "co", "del", "er", "fo", "gra", "hu", "in", "jo",
        "ka", "lo", "mi", "nor", "ol", "pe", "qua", "ro", "sa", "tu"]


def _word(rng, lo=2, hi=4):
    return "".join(rng.choice(SYLL) for _ in range(rng.integers(lo, hi + 1)))


def make_dataset(name: str, n: int, seed: int = 7) -> Tuple[List, int]:
    """-> (keys, key_width). Distributions mirror paper Table 2."""
    rng = np.random.default_rng(seed)
    if name == "rand-int":
        ks = set()
        while len(ks) < n:
            ks.update(rng.integers(0, 2**63, size=n).tolist())
        return [int(x) for x in list(ks)[:n]], 8
    out = set()
    if name == "3-gram":          # ~16B: three short words
        while len(out) < n:
            out.add(f"{_word(rng)} {_word(rng)} {_word(rng)}".encode()[:38])
        width = 40
    elif name == "ycsb":          # ~23B: user<zero-padded counter hash>
        while len(out) < n:
            out.add(f"user{rng.integers(0, 10**18):019d}".encode())
        width = 24
    elif name == "twitter":       # ~52B: cluster-prefixed anonymized ids
        clusters = [f"c{c:02d}:ns{rng.integers(0,99):02d}:" for c in range(24)]
        while len(out) < n:
            pre = clusters[int(rng.zipf(1.3)) % len(clusters)]
            body = bytes(rng.integers(97, 123, size=40, dtype=np.uint8))
            out.add(pre.encode() + body)
        width = 52
    elif name == "url":           # ~70B: heavy shared prefixes
        hosts = ["http://dbpedia.org/resource/", "http://example.com/a/b/",
                 "https://api.service.io/v2/items/",
                 "http://news.site.net/2024/"]
        while len(out) < n:
            h = hosts[int(rng.zipf(1.2)) % len(hosts)]
            tail = f"{_word(rng)}/{_word(rng)}_{rng.integers(0, 10**9)}"
            out.add((h + tail).encode()[:72])
        width = 72
    else:
        raise KeyError(name)
    return sorted(out)[:n] if len(out) >= n else list(out), width


DATASETS = ("rand-int", "3-gram", "ycsb", "twitter", "url")


def build_tree(keys, width, fs: int = 4, ns: int = 64,
               stacked: bool = False) -> Tuple[FBTree, K.KeySet]:
    ks = K.make_keyset(keys, width)
    cfg = TreeConfig.plan(max_keys=int(len(keys) * 2.5), key_width=width,
                          fs=fs, ns=ns, stacked=stacked)
    vals = np.arange(len(keys), dtype=np.int32)
    return bulk_build(cfg, ks, vals), ks


def make_engine(backend: str = "jnp", layout: str = None) -> TraversalEngine:
    """CLI-facing engine selector: turns constructor validation errors into
    a clean SystemExit before a long benchmark run starts."""
    try:
        return TraversalEngine(backend=backend, layout=layout)
    except ValueError as e:
        raise SystemExit(f"bad --backend/--layout: {e}")


def zipf_indices(rng, n_keys: int, n_ops: int, theta: float) -> np.ndarray:
    """Zipfian (skew=theta) request indices over n_keys (YCSB default .99)."""
    if theta <= 0.01:
        return rng.integers(0, n_keys, size=n_ops)
    # standard YCSB zipf via rejection-free inverse CDF approximation
    ranks = np.arange(1, n_keys + 1, dtype=np.float64)
    w = ranks ** (-theta)
    cdf = np.cumsum(w) / w.sum()
    u = rng.random(n_ops)
    idx = np.searchsorted(cdf, u)
    perm = rng.permutation(n_keys)    # decorrelate rank from key order
    return perm[np.clip(idx, 0, n_keys - 1)]


def timed(fn: Callable, *args, warmup: int = 1, iters: int = 3) -> float:
    """Median wall time of a jitted batched call (block_until_ready)."""
    for _ in range(warmup):
        out = fn(*args)
        jax.block_until_ready(out)
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def fmt_table(rows: List[Dict], cols: Sequence[str]) -> str:
    widths = {c: max(len(c), *(len(str(r.get(c, ""))) for r in rows))
              for c in cols}
    line = "  ".join(c.ljust(widths[c]) for c in cols)
    out = [line, "  ".join("-" * widths[c] for c in cols)]
    for r in rows:
        out.append("  ".join(str(r.get(c, "")).ljust(widths[c])
                             for c in cols))
    return "\n".join(out)
