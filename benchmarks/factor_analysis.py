"""Structural-optimization factor analysis (paper Fig. 12a).

Enable optimizations one at a time on the SAME key sets:
  base -> +prefix -> +feature2 -> +feature4 -> +hashtag
reporting throughput and machine-independent counters (full-key compares
and modeled 64B cache lines per lookup).
"""
from __future__ import annotations

from typing import Dict, List

import jax.numpy as jnp
import numpy as np

from repro.core import keys as K
from repro.core.baseline import lookup_variant
from repro.core.fbtree import TreeConfig, bulk_build

from .common import build_tree, make_dataset, make_engine, timed, zipf_indices

STEPS = ("base", "+prefix", "+feature2", "+feature4", "+hashtag")


def run(datasets=("3-gram", "ycsb", "twitter", "url"), n_keys=20_000,
        n_ops=16_384, seed=13, backend="jnp", layout=None) -> List[Dict]:
    engine = make_engine(backend, layout)
    rows = []
    rng = np.random.default_rng(seed)
    for ds in datasets:
        keys, width = make_dataset(ds, n_keys)
        ks = K.make_keyset(keys, width)
        idx = zipf_indices(rng, len(keys), n_ops, 0.99)
        qb, ql = jnp.asarray(ks.bytes[idx]), jnp.asarray(ks.lens[idx])
        trees = {}
        for fs in (2, 4):
            cfg = TreeConfig.plan(max_keys=2 * n_keys, key_width=width, fs=fs,
                                  stacked=(layout == "stacked"))
            trees[fs] = bulk_build(cfg, ks, np.arange(n_keys, dtype=np.int32))
        plan = [("base", trees[4], "base"),
                ("+prefix", trees[4], "prefix"),
                ("+feature2", trees[2], "feature"),
                ("+feature4", trees[4], "feature"),
                ("+hashtag", trees[4], "feature+hash")]
        for label, tree, variant in plan:
            def fn():
                outs = []
                for off in range(0, n_ops, 4096):
                    f, v, st, ls = lookup_variant(tree, qb[off:off + 4096],
                                                  ql[off:off + 4096],
                                                  variant=variant,
                                                  engine=engine)
                    outs.append(v)
                return outs
            t = timed(fn)
            _, _, st, ls = lookup_variant(tree, qb[:4096], ql[:4096],
                                          variant=variant, engine=engine)
            rows.append({
                "dataset": ds, "step": label, "backend": backend,
                "Mops": round(n_ops / t / 1e6, 3),
                "key_cmp/op": round(float(st.key_compares.mean()), 2),
                "lines/op": round(float(st.lines_touched.mean()), 1),
                "suffix_bs/op": round(float(st.suffix_bs.mean()), 3),
            })
    return rows


COLUMNS = ["dataset", "step", "backend", "Mops", "key_cmp/op", "lines/op",
           "suffix_bs/op"]
