"""Index memory consumption (paper Fig. 12b).

Bytes/key of the FB+-tree arrays vs (a) a typical B+-tree that copies full
anchor keys into inner nodes (STX-style model) and (b) a sorted array+
pointers lower bound. FB+-tree stores only anchor *pointers* (key ids) +
fs feature bytes — the paper's space claim.
"""
from __future__ import annotations

from typing import Dict, List

import numpy as np

from .common import DATASETS, build_tree, make_dataset


def _tree_bytes(tree, live_only=True) -> int:
    a = tree.arrays
    total = 0
    n_leaf = int(a.leaf_count)
    nk = int(a.key_count)
    ns = tree.config.ns
    total += nk * (tree.config.key_width + 4 + 1)       # key pool+len+tag
    total += n_leaf * (ns * (1 + 4 + 4 + 1) + 8 + 4 + 4 + 4)  # leaf arrays
    for li, lvl in enumerate(tree.arrays.levels):
        c = int(lvl.count)
        total += c * (4 + 4 + tree.config.key_width
                      + tree.config.fs * ns + 2 * 4 * ns)
    return total


def _stx_model_bytes(n_keys: int, width: int, fanout=64, fill=0.67) -> int:
    """Typical B+-tree: sorted leaves with (key,val) pairs; inner nodes copy
    full anchor keys + child pointers."""
    leaves = int(np.ceil(n_keys / (fanout * fill)))
    total = n_keys * (width + 8)                 # leaf key copies + values
    n = leaves
    while n > 1:
        parents = int(np.ceil(n / (fanout * fill)))
        total += n * (width + 8)                 # anchor copy + child ptr
        n = parents
    total += leaves * 16                         # siblings, counts
    return total


def run(datasets=DATASETS, n_keys=20_000) -> List[Dict]:
    rows = []
    for ds in datasets:
        keys, width = make_dataset(ds, n_keys)
        tree, ks = build_tree(keys, width)
        fb = _tree_bytes(tree)
        stx = _stx_model_bytes(len(keys), int(np.mean([len(k) if not
                               isinstance(k, int) else 8 for k in keys])))
        flat = len(keys) * (width + 8 + 4)
        rows.append({
            "dataset": ds,
            "fb_B/key": round(fb / len(keys), 1),
            "stx_model_B/key": round(stx / len(keys), 1),
            "sorted_array_B/key": round(flat / len(keys), 1),
            "fb_vs_stx": round(fb / stx, 2),
        })
    return rows


COLUMNS = ["dataset", "fb_B/key", "stx_model_B/key", "sorted_array_B/key",
           "fb_vs_stx"]
