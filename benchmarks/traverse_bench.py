"""Traversal engine A/B: backends (jnp vs pallas-interpret vs the fused
whole-descent kernel) × layouts (tuple vs stacked) × stats (on vs the
stats-free hot path) on identical trees and query streams — plus the build
benchmark (:func:`run_build`): host-numpy vs device-jnp ``bulk_build``
across datasets and tree sizes, with a bit-exact parity cross-check
(DESIGN.md §5).

Cross-checks that every stats-on combination returns identical leaf ids and
machine-independent counters (``key_compares``, ``suffix_bs``,
``feat_rounds``) and that every stats-off combination returns identical
``found`` — the engine contract (the check runs the FULL lookup pipeline,
descent + hashtag probe) — then reports relative throughput. Since PR 3
the ``Mops`` column times the *engine descent* (``batch_ops.traverse_path``,
the code the backends actually differ on) rather than the whole lookup, so
``Mops`` is not comparable to pre-PR3 rows; the counter columns are
unchanged and stay comparable. Results land in ``BENCH_traverse.json`` at
the repo root (``rows`` = traversal A/B, ``build_rows`` = host-vs-device
build) so the perf trajectory of future kernel PRs starts here.

``smoke=True`` is the CI mode (`benchmarks/run.py --suite traverse
--smoke`): tiny trees, one timing iteration, every backend including
``fused`` in interpret mode — the parity asserts are the point; a
kernel-path regression fails CI instead of rotting until the next bench
run.
"""
from __future__ import annotations

import json
import os
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import batch_ops as B
from repro.core import keys as K
from repro.core.fbtree import TreeConfig, bulk_build
from repro.core.traverse import TraversalEngine

from .common import build_tree, make_dataset, timed, zipf_indices

COMBOS = [("jnp", "tuple"), ("jnp", "stacked"),
          ("pallas", "tuple"), ("pallas", "stacked"),
          ("fused", "stacked")]


def run(datasets=("ycsb", "url"), n_keys=20_000, n_ops=16_384,
        seed=23, smoke: bool = False) -> List[Dict]:
    if smoke:
        datasets = ("ycsb",)
        n_keys, n_ops = 600, 512
    rows = []
    rng = np.random.default_rng(seed)
    chunk = min(4096, n_ops)
    for ds in datasets:
        keys, width = make_dataset(ds, n_keys)
        tree, ks = build_tree(keys, width)
        idx = zipf_indices(rng, len(keys), n_ops, 0.99)
        qb, ql = jnp.asarray(ks.bytes[idx]), jnp.asarray(ks.lens[idx])
        ref = None
        ref_found = None
        for backend, layout in COMBOS:
            for stats_on in (True, False):
                eng = TraversalEngine(backend=backend, layout=layout,
                                      collect_stats=stats_on)
                def fn():
                    outs = []
                    for off in range(0, n_ops, chunk):
                        leaf, _, _ = B.traverse_path(tree, qb[off:off + chunk],
                                                     ql[off:off + chunk],
                                                     engine=eng)
                        outs.append(leaf)
                    return outs
                t = timed(fn, warmup=1 if smoke else 2,
                          iters=1 if smoke else 7)
                _, rep = B.lookup_batch(tree, qb[:chunk], ql[:chunk],
                                        engine=eng)
                if stats_on:
                    sig = (np.asarray(rep.found),
                           np.asarray(rep.key_compares),
                           np.asarray(rep.suffix_bs),
                           np.asarray(rep.feat_rounds))
                    if ref is None:
                        ref, ref_found = sig, sig[0]
                    else:
                        for a, b, nm in zip(ref, sig,
                                            ("found", "key_compares",
                                             "suffix_bs", "feat_rounds")):
                            assert (a == b).all(), \
                                f"{ds}: {backend}/{layout} diverges on {nm}"
                else:
                    # stats-free contract: counters are zero by design,
                    # found-ness must still match the stats-on reference
                    assert (np.asarray(rep.found) == ref_found).all(), \
                        f"{ds}: {backend}/{layout} stats-off diverges"
                row = {
                    "dataset": ds, "n_keys": len(keys), "n_ops": n_ops,
                    "backend": backend, "layout": layout,
                    "stats": "on" if stats_on else "off",
                    "Mops": round(n_ops / t / 1e6, 3),
                    "parity": "ok",
                }
                if stats_on:
                    row.update({
                        "key_cmp/op": round(float(rep.key_compares.mean()), 2),
                        "suffix_bs/op": round(float(rep.suffix_bs.mean()), 3),
                        "feat_rounds/op": round(float(rep.feat_rounds.mean()), 2),
                    })
                rows.append(row)
    return rows


# n_keys/n_ops ride along so the trajectory anchor stays comparable across
# PRs — counters like key_cmp/op shift with tree size, not just with code
COLUMNS = ["dataset", "n_keys", "n_ops", "backend", "layout", "stats",
           "Mops", "key_cmp/op", "suffix_bs/op", "feat_rounds/op", "parity"]


def run_build(datasets=("ycsb", "url"), sizes=(5_000, 20_000),
              rebuild_frac=0.3, seed=23) -> List[Dict]:
    """Host vs device ``bulk_build`` (+ ``rebuild``) across datasets/sizes.

    For each (dataset, n_keys): time the numpy host build, the jit device
    build, and a device ``rebuild`` after tombstoning ``rebuild_frac`` of the
    keys; verify host and device builds are bit-identical (the DESIGN.md §5
    parity contract) before reporting. On the CPU backend the device rows are
    relative anchors only (XLA-CPU gathers lose to numpy at these sizes); the
    win the rows track is device residency — no host round-trip, and
    ``rebuild`` composing under jit with the serving loop.
    """
    rows = []
    for ds in datasets:
        for n_keys in sizes:
            keys, width = make_dataset(ds, n_keys, seed=seed)
            ks = K.make_keyset(keys, width)
            cfg = TreeConfig.plan(max_keys=int(len(keys) * 2.5),
                                  key_width=width)
            vals = np.arange(len(keys), dtype=np.int32)
            def _equal(ta, tb):
                return all(
                    (np.asarray(x) == np.asarray(y)).all()
                    for x, y in zip(jax.tree_util.tree_leaves(ta.arrays),
                                    jax.tree_util.tree_leaves(tb.arrays)))

            th = bulk_build(cfg, ks, vals)
            td = bulk_build(cfg, ks, vals, device=True)
            parity = _equal(th, td)
            t_host = timed(lambda: bulk_build(cfg, ks, vals))
            t_dev = timed(lambda: bulk_build(cfg, ks, vals, device=True))
            n_rm = int(len(keys) * rebuild_frac)
            rm = K.make_keyset(keys[:n_rm], width)
            tfrag, _ = B.remove_batch(td, jnp.asarray(rm.bytes),
                                      jnp.asarray(rm.lens))
            t_reb = timed(lambda: B.rebuild(tfrag))
            # rebuild's own §5 contract: equals a fresh build of the live set
            trebuilt, _ = B.rebuild(tfrag)
            tref = bulk_build(cfg, K.make_keyset(keys[n_rm:], width),
                              vals[n_rm:], device=True)
            reb_parity = _equal(trebuilt, tref)
            for mode, t, ok in (("host", t_host, parity),
                                ("device", t_dev, parity),
                                ("rebuild", t_reb, reb_parity)):
                rows.append({
                    "dataset": ds, "n_keys": len(keys), "mode": mode,
                    "build_ms": round(t * 1e3, 2),
                    "Mkeys/s": round(len(keys) / t / 1e6, 3),
                    "parity": "ok" if ok else "MISMATCH",
                })
    return rows


BUILD_COLUMNS = ["dataset", "n_keys", "mode", "build_ms", "Mkeys/s",
                 "parity"]


def write_json(rows: List[Dict] = None, build_rows: List[Dict] = None,
               scan_rows: List[Dict] = None, shard_rows: List[Dict] = None,
               path: str = None) -> str:
    """Merge the given section(s) into ``BENCH_traverse.json`` — the perf
    trajectory anchor accumulates (``rows`` = traversal A/B, ``build_rows``
    = host-vs-device build, ``scan_rows`` = scan-engine A/B, ``shard_rows``
    = sharded-tree 1/2/4-shard A/B); suites never clobber each other."""
    if path is None:
        path = os.path.join(os.path.dirname(os.path.dirname(__file__)),
                            "BENCH_traverse.json")
    data = {"suite": "traverse"}
    if os.path.exists(path):
        with open(path) as f:
            data = json.load(f)
    if rows is not None:
        data["rows"] = rows
    if build_rows is not None:
        data["build_rows"] = build_rows
    if scan_rows is not None:
        data["scan_rows"] = scan_rows
    if shard_rows is not None:
        data["shard_rows"] = shard_rows
    with open(path, "w") as f:
        json.dump(data, f, indent=2)
        f.write("\n")
    return path
