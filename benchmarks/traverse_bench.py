"""Traversal engine A/B: backends (jnp vs pallas-interpret) × layouts
(tuple vs stacked) on identical trees and query streams.

Cross-checks that every combination returns identical leaf ids and
machine-independent counters (``key_compares``, ``suffix_bs``,
``feat_rounds``) — the engine contract — then reports relative lookup
throughput. Results also land in ``BENCH_traverse.json`` at the repo root
so the perf trajectory of future kernel PRs starts here.
"""
from __future__ import annotations

import json
import os
from typing import Dict, List

import jax.numpy as jnp
import numpy as np

from repro.core import batch_ops as B
from repro.core.traverse import TraversalEngine

from .common import build_tree, make_dataset, timed, zipf_indices

COMBOS = [("jnp", "tuple"), ("jnp", "stacked"),
          ("pallas", "tuple"), ("pallas", "stacked")]


def run(datasets=("ycsb", "url"), n_keys=20_000, n_ops=16_384,
        seed=23) -> List[Dict]:
    rows = []
    rng = np.random.default_rng(seed)
    for ds in datasets:
        keys, width = make_dataset(ds, n_keys)
        tree, ks = build_tree(keys, width)
        idx = zipf_indices(rng, len(keys), n_ops, 0.99)
        qb, ql = jnp.asarray(ks.bytes[idx]), jnp.asarray(ks.lens[idx])
        ref = None
        for backend, layout in COMBOS:
            eng = TraversalEngine(backend=backend, layout=layout)
            def fn():
                outs = []
                for off in range(0, n_ops, 4096):
                    v, rep = B.lookup_batch(tree, qb[off:off + 4096],
                                            ql[off:off + 4096], engine=eng)
                    outs.append(v)
                return outs
            t = timed(fn)
            _, rep = B.lookup_batch(tree, qb[:4096], ql[:4096], engine=eng)
            sig = (np.asarray(rep.found), np.asarray(rep.key_compares),
                   np.asarray(rep.suffix_bs), np.asarray(rep.feat_rounds))
            if ref is None:
                ref = sig
            else:
                for a, b, nm in zip(ref, sig, ("found", "key_compares",
                                               "suffix_bs", "feat_rounds")):
                    assert (a == b).all(), \
                        f"{ds}: {backend}/{layout} diverges on {nm}"
            rows.append({
                "dataset": ds, "backend": backend, "layout": layout,
                "Mops": round(n_ops / t / 1e6, 3),
                "key_cmp/op": round(float(rep.key_compares.mean()), 2),
                "suffix_bs/op": round(float(rep.suffix_bs.mean()), 3),
                "feat_rounds/op": round(float(rep.feat_rounds.mean()), 2),
                "parity": "ok",
            })
    return rows


COLUMNS = ["dataset", "backend", "layout", "Mops", "key_cmp/op",
           "suffix_bs/op", "feat_rounds/op", "parity"]


def write_json(rows: List[Dict], path: str = None) -> str:
    if path is None:
        path = os.path.join(os.path.dirname(os.path.dirname(__file__)),
                            "BENCH_traverse.json")
    with open(path, "w") as f:
        json.dump({"suite": "traverse", "rows": rows}, f, indent=2)
        f.write("\n")
    return path
