"""Range-scan benchmark (YCSB-E side of paper Fig. 17): scan throughput and
lazy-rearrangement cost — FB+-tree's balanced leaf chain vs re-walking the
index per item (trie-pointer-chasing model).
"""
from __future__ import annotations

from typing import Dict, List

import jax.numpy as jnp
import numpy as np

from repro.core import batch_ops as B
from repro.core import keys as K

from .common import build_tree, make_dataset, timed, zipf_indices


def run(datasets=("rand-int", "ycsb", "url"), n_keys=20_000, n_scans=512,
        scan_len=100, seed=31) -> List[Dict]:
    rows = []
    rng = np.random.default_rng(seed)
    for ds in datasets:
        keys, width = make_dataset(ds, n_keys)
        tree, ks = build_tree(keys, width)
        idx = rng.integers(0, n_keys, size=n_scans)
        qb, ql = jnp.asarray(ks.bytes[idx]), jnp.asarray(ks.lens[idx])

        def scan_fn():
            kid, val, em, re_ = B.range_scan(tree, qb, ql,
                                             max_items=scan_len)
            return val
        t = timed(scan_fn)

        # pointer-chasing model: each successor found by a fresh root
        # descent (what a trie iterator without leaf links pays)
        def chase_fn():
            out = []
            for _ in range(4):      # sample: 4 hops via full descents
                v, _ = B.lookup_batch(tree, qb, ql)
                out.append(v)
            return out
        t_chase = timed(chase_fn) * (scan_len / 4)

        # lazy rearrangement: scan after updates dirty half the leaves
        upd = rng.integers(0, n_keys, size=4096)
        t2, _ = B.update_batch(tree, jnp.asarray(ks.bytes[upd]),
                               jnp.asarray(ks.lens[upd]),
                               jnp.arange(4096, dtype=jnp.int32))
        def scan_dirty():
            kid, val, em, re_ = B.range_scan(t2, qb, ql,
                                             max_items=scan_len)
            return val
        t_dirty = timed(scan_dirty)
        rows.append({
            "dataset": ds,
            "scan_Mitems": round(n_scans * scan_len / t / 1e6, 3),
            "chase_model_Mitems": round(n_scans * scan_len / t_chase / 1e6,
                                        3),
            "speedup_vs_chase": round(t_chase / t, 1),
            "dirty_scan_penalty": round(t_dirty / t, 2),
        })
    return rows


COLUMNS = ["dataset", "scan_Mitems", "chase_model_Mitems",
           "speedup_vs_chase", "dirty_scan_penalty"]
