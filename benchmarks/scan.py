"""Range-scan benchmark (YCSB-E side of paper Fig. 17): scan engine A/B.

Per dataset, times the scan engine's two backends (DESIGN.md §6) on the
same trees and query streams:

* ``jnp``   — the chain-walk reference (engine descent + early-exit
  ``while_loop`` + lazy-rearrangement cond);
* ``fused`` — the whole-scan Pallas kernel (``kernels/fused_scan``,
  interpret mode off-TPU).

Each backend is measured on an all-ordered tree (``scan_Mitems`` — the
lazy-rearrangement fast path, no per-hop sorting) and on a tree whose
leaves were dirtied by in-place inserts (``dirty_Mitems`` — the sort cond
fires). ``alwayssort_Mitems`` is the pre-scan-engine baseline (the old
``range_scan`` sorted every visited leaf on every hop; ``force_sort=True``
reproduces it bit-identically), so ``speedup_vs_alwayssort`` is the win the
ordered fast path carries into the anchor. The trie-pointer-chasing model
(each successor found by a fresh root descent) stays for paper context.

Every row cross-checks both backends and the always-sort baseline for
bit-identical emissions before timing — a scan-kernel regression fails the
suite (and CI, via ``--smoke``) rather than reporting wrong throughput.
Rows land in ``BENCH_traverse.json`` under ``scan_rows``.
"""
from __future__ import annotations

from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import batch_ops as B
from repro.core import keys as K
from repro.core.traverse import TraversalEngine

from .common import build_tree, make_dataset, timed

BACKENDS = ("jnp", "fused")


def _dirty_tree(tree, ks, rng, n_extra):
    """In-place-insert siblings of existing keys so leaves ACROSS the
    scanned range drop ``leaf_ordered`` (the §4.5 lazy-rearrangement
    scenario). Perturbing the last byte of a sampled key keeps the new key
    inside the same (populated) leaf — inserting unrelated random keys
    would funnel into the range's edge leaves and split into *ordered*
    chunks, leaving the scan path clean. Returns the dirtied tree."""
    seen = {bytes(ks.bytes[i][:ks.lens[i]].tobytes()) for i in range(ks.n)}
    extra = []
    for i in rng.permutation(ks.n):
        if len(extra) >= n_extra:
            break
        b, ln = ks.bytes[i].copy(), int(ks.lens[i])
        b[ln - 1] ^= 0xA5
        cand = bytes(b[:ln].tobytes())
        if cand not in seen:
            seen.add(cand)
            extra.append(cand)
    eks = K.make_keyset(extra, ks.bytes.shape[1])
    tree, _, _ = B.insert_batch(tree, eks.bytes, eks.lens,
                                np.arange(len(extra), dtype=np.int32)
                                + (1 << 20))
    n_dirty = int((~np.asarray(tree.arrays.leaf_ordered)
                   [:int(tree.arrays.leaf_count)]).sum())
    assert n_dirty > 0, "dirtying produced no unordered leaves"
    return tree


def run(datasets=("rand-int", "ycsb", "url"), n_keys=20_000, n_scans=512,
        scan_len=100, seed=31, smoke: bool = False) -> List[Dict]:
    if smoke:
        datasets = ("ycsb",)
        n_keys, n_scans, scan_len = 600, 128, 24
    rows = []
    rng = np.random.default_rng(seed)
    for ds in datasets:
        keys, width = make_dataset(ds, n_keys)
        tree, ks = build_tree(keys, width)
        t_dirty = _dirty_tree(tree, ks, rng, max(16, n_keys // 16))
        idx = rng.integers(0, n_keys, size=n_scans)
        qb, ql = jnp.asarray(ks.bytes[idx]), jnp.asarray(ks.lens[idx])

        # ---- parity gate: both backends + the always-sort baseline emit
        # bit-identical pairs on the ordered AND the dirtied tree
        ref = {}
        # ONE compiled always-sort baseline serves both the parity gate and
        # the timing below (stats-off: kid/val/emitted are bit-identical
        # either way, and timing runs the serving configuration)
        slow_ref = jax.jit(lambda t: B._range_scan_jnp(
            t, qb, ql, scan_len, TraversalEngine("jnp", collect_stats=False),
            force_sort=True))
        for label, t in (("ordered", tree), ("dirty", t_dirty)):
            ref[label] = [np.asarray(x) for x in B.range_scan(
                t, qb, ql, max_items=scan_len, engine=TraversalEngine("jnp"))]
            slow = slow_ref(t)
            for a, b in zip(ref[label][:3], slow[:3]):
                assert (a == np.asarray(b)).all(), \
                    f"{ds}/{label}: always-sort baseline diverges"

        # pointer-chasing model: each successor found by a fresh root
        # descent (what a trie iterator without leaf links pays)
        def chase_fn():
            out = []
            for _ in range(4):      # sample: 4 hops via full descents
                v, _ = B.lookup_batch(tree, qb, ql)
                out.append(v)
            return out
        t_chase = timed(chase_fn, warmup=1, iters=1 if smoke else 3) \
            * (scan_len / 4)

        for backend in BACKENDS:
            # throughput runs stats-free (the serving configuration);
            # parity was pinned above with stats on
            eng = TraversalEngine(backend=backend,
                                  layout="stacked" if backend == "fused"
                                  else None,
                                  collect_stats=False)
            for label, t in (("ordered", tree), ("dirty", t_dirty)):
                got = B.range_scan(t, qb, ql, max_items=scan_len, engine=eng)
                for a, b, nm in zip(ref[label][:3], got[:3],
                                    ("kid", "val", "emitted")):
                    assert (a == np.asarray(b)).all(), \
                        f"{ds}/{label}: {backend} diverges on {nm}"

            def scan_fn(t):
                return B.range_scan(t, qb, ql, max_items=scan_len,
                                    engine=eng)[1]
            t_ord = timed(lambda: scan_fn(tree), warmup=1,
                          iters=1 if smoke else 5)
            t_dirt = timed(lambda: scan_fn(t_dirty), warmup=1,
                           iters=1 if smoke else 5)
            row = {
                "dataset": ds, "n_keys": n_keys, "n_scans": n_scans,
                "scan_len": scan_len, "backend": backend,
                "scan_Mitems": round(n_scans * scan_len / t_ord / 1e6, 3),
                "dirty_Mitems": round(n_scans * scan_len / t_dirt / 1e6, 3),
                "chase_model_Mitems": round(
                    n_scans * scan_len / t_chase / 1e6, 3),
                "parity": "ok",
            }
            if backend == "jnp":
                # the pre-engine baseline: every visited leaf re-sorted on
                # every hop (bit-identical outputs, checked above; reuses
                # the parity gate's compiled slow_ref)
                t_slow = timed(lambda: slow_ref(tree)[1], warmup=1,
                               iters=1 if smoke else 5)
                row["alwayssort_Mitems"] = round(
                    n_scans * scan_len / t_slow / 1e6, 3)
                row["speedup_vs_alwayssort"] = round(t_slow / t_ord, 2)
            rows.append(row)
    return rows


COLUMNS = ["dataset", "n_keys", "n_scans", "scan_len", "backend",
           "scan_Mitems", "dirty_Mitems", "alwayssort_Mitems",
           "speedup_vs_alwayssort", "chase_model_Mitems", "parity"]
