"""YCSB core workloads (paper Fig. 11 / Fig. 17 analogue).

LOAD (100% insert), A (50% read / 50% update), C (100% read),
E (95% scan / 5% insert) over the five datasets, for the FB+-tree and the
binary-search B+-tree baseline (same arrays — the paper's STX/B+-treeOLC
stand-in). Zipfian requests, skew 0.99 (YCSB default).
"""
from __future__ import annotations

from typing import Dict, List

import jax.numpy as jnp
import numpy as np

from repro.core import batch_ops as B
from repro.core import keys as K
from repro.core.baseline import lookup_variant

from .common import (DATASETS, build_tree, make_dataset, make_engine, timed,
                     zipf_indices)

N_KEYS = 20_000
N_OPS = 40_960
BATCH = 4096
SKEW = 0.99


def run(datasets=DATASETS, n_keys=N_KEYS, n_ops=N_OPS, seed=11,
        backend="jnp", layout=None) -> List[Dict]:
    engine = make_engine(backend, layout)
    rows = []
    rng = np.random.default_rng(seed)
    for ds in datasets:
        keys, width = make_dataset(ds, n_keys)
        tree, ks = build_tree(keys, width, stacked=(layout == "stacked"))
        idx = zipf_indices(rng, len(keys), n_ops, SKEW)
        qb = jnp.asarray(ks.bytes[idx])
        ql = jnp.asarray(ks.lens[idx])
        row = {"dataset": ds}

        # ---- LOAD: bulk insert fresh keys batch-by-batch
        fresh, _ = make_dataset(ds, n_keys // 2, seed + 1)
        fresh = [k for k in fresh if k not in set(keys)][:BATCH * 2]
        fks = K.make_keyset(fresh, width)
        def load_fn(t=tree):
            out = t
            for off in range(0, len(fresh), BATCH):
                nb = jnp.asarray(fks.bytes[off:off + BATCH])
                nl = jnp.asarray(fks.lens[off:off + BATCH])
                out, _, _ = B.insert_batch(out, nb, nl,
                                           jnp.arange(nb.shape[0]),
                                           engine=engine)
            return out.arrays.leaf_occ
        t_load = timed(load_fn, warmup=1, iters=2)
        row["LOAD_Mops"] = round(len(fresh) / t_load / 1e6, 3)

        # ---- C: 100% read, fb vs binary baseline
        for var, label in (("feature+hash", "fb"), ("base", "btree")):
            def read_fn(v=var):
                outs = []
                for off in range(0, n_ops, BATCH):
                    f, val, st, ls = lookup_variant(
                        tree, qb[off:off + BATCH], ql[off:off + BATCH],
                        variant=v, engine=engine)
                    outs.append(val)
                return outs
            t = timed(read_fn)
            row[f"C_{label}_Mops"] = round(n_ops / t / 1e6, 3)

        # ---- A: 50/50 read/update
        upd_vals = jnp.arange(BATCH, dtype=jnp.int32)
        def a_fn():
            t2 = tree
            outs = []
            for off in range(0, n_ops, BATCH * 2):
                f, val, _, _ = lookup_variant(
                    tree, qb[off:off + BATCH], ql[off:off + BATCH],
                    variant="feature+hash", engine=engine)
                t2, _ = B.update_batch(t2, qb[off + BATCH:off + 2 * BATCH],
                                       ql[off + BATCH:off + 2 * BATCH],
                                       upd_vals, engine=engine)
                outs.append(val)
            return t2.arrays.leaf_val
        t_a = timed(a_fn)
        row["A_Mops"] = round(n_ops / t_a / 1e6, 3)

        # ---- E: 95% short scan (50 items) / 5% insert
        n_scan = 1024
        sb, sl = qb[:n_scan], ql[:n_scan]
        def e_fn():
            kid, val, em, _ = B.range_scan(tree, sb, sl, max_items=50,
                                           engine=engine)
            return val
        t_e = timed(e_fn)
        row["E_Mops"] = round(n_scan * 50 / t_e / 1e6, 3)  # items/s
        rows.append(row)
    return rows


COLUMNS = ["dataset", "LOAD_Mops", "A_Mops", "C_fb_Mops", "C_btree_Mops",
           "E_Mops"]
