"""Roofline tables from dry-run JSON (EXPERIMENTS.md §Dry-run / §Roofline).

Reads out/dryrun_single.json (+ optional multi/variant files) and renders
the 40-cell baseline table with the three roofline terms, dominant
bottleneck, useful-FLOP ratio and an MFU bound.
"""
from __future__ import annotations

import json
import os
from typing import Dict, List

COLUMNS = ["arch", "shape", "mesh", "status", "dom", "compute_s",
           "memory_s", "collective_s", "useful", "mfu_bound", "params_B"]


def load(path: str) -> List[Dict]:
    if not os.path.exists(path):
        return []
    with open(path) as f:
        return json.load(f)


def rows_from(recs: List[Dict]) -> List[Dict]:
    rows = []
    for r in recs:
        row = {"arch": r["arch"], "shape": r["shape"], "mesh": r["mesh"],
               "status": r["status"]}
        if r["status"] == "ok":
            rf = r["roofline"]
            row.update(
                dom=rf["dominant"],
                compute_s=round(rf["compute_s"], 3),
                memory_s=round(rf["memory_s"], 3),
                collective_s=round(rf["collective_s"], 3),
                useful=round(rf["useful_flop_ratio"], 3),
                mfu_bound=round(rf["mfu_bound"], 4),
                params_B=round(r["params_total"] / 1e9, 1),
            )
        elif r["status"] == "skipped":
            row["dom"] = "(skip: sub-quadratic attention required)"
        else:
            row["dom"] = r.get("error", "")[:60]
        rows.append(row)
    return rows


def run(paths=("out/dryrun_single.json", "out/dryrun_multi.json")):
    out = []
    for p in paths:
        out.extend(rows_from(load(p)))
    return out
