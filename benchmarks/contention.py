"""Update contention (paper Fig. 14 / Fig. 15 analogue).

Two measurements:
 1. Batched-engine view: YCSB-A updates under varying zipf skew — the
    latch-free batch commits once per batch; the "lock" baseline's cost is
    modeled by its serialization factor (max conflict-group size = the
    queue depth on the hottest leaf/lock), reported alongside measured
    batched throughput.
 2. Protocol-simulator view: interleaved updates on a small tree under a
    random scheduler — retries per committed update for (a) latch-free CAS
    updates vs (b) lock-acquire updates, as contention rises.
"""
from __future__ import annotations

import random
from typing import Dict, List

import jax.numpy as jnp
import numpy as np

from repro.core import batch_ops as B
from repro.core import keys as K
from repro.core.protocol import Sim, run_schedule

from .common import build_tree, make_dataset, timed, zipf_indices


def run_batched(n_keys=20_000, n_ops=32_768, skews=(0.01, 0.7, 0.99, 1.2),
                seed=19) -> List[Dict]:
    rows = []
    rng = np.random.default_rng(seed)
    keys, width = make_dataset("rand-int", n_keys)
    tree, ks = build_tree(keys, width)
    for skew in skews:
        idx = zipf_indices(rng, n_keys, n_ops, skew)
        qb, ql = jnp.asarray(ks.bytes[idx]), jnp.asarray(ks.lens[idx])
        vals = jnp.arange(n_ops, dtype=jnp.int32)
        def fn():
            t2 = tree
            for off in range(0, n_ops, 4096):
                t2, _ = B.update_batch(t2, qb[off:off + 4096],
                                       ql[off:off + 4096],
                                       vals[off:off + 4096])
            return t2.arrays.leaf_val
        t = timed(fn)
        # conflict structure of one batch
        _, rep = B.update_batch(tree, qb[:4096], ql[:4096], vals[:4096])
        uniq, counts = np.unique(idx[:4096], return_counts=True)
        # lock-baseline model: a per-leaf lock serializes every op that maps
        # to the same leaf; hottest leaf bounds the critical path
        leaf_of = np.asarray(
            B.traverse_path(tree, qb[:4096], ql[:4096])[0])
        _, leaf_counts = np.unique(leaf_of, return_counts=True)
        rows.append({
            "skew": skew,
            "upd_Mops": round(n_ops / t / 1e6, 3),
            "dup_ops_in_batch": int(rep.conflicts),
            "hottest_key": int(counts.max()),
            "hottest_leaf": int(leaf_counts.max()),
            "lock_serial_factor": round(float(leaf_counts.max())
                                        / max(1.0, leaf_counts.mean()), 1),
        })
    return rows


def run_protocol(n_threads=(2, 4, 8, 16), hot_keys=4, seed=23) -> List[Dict]:
    rows = []
    for nt in n_threads:
        rnd = random.Random(seed + nt)
        # latch-free: count CAS retries (yield points beyond minimum)
        sim = Sim(keys=range(hot_keys))
        gens = [sim.update(rnd.randrange(hot_keys), ("u", i))
                for i in range(nt * 4)]
        steps = 0
        live = list(gens)
        while live:
            i = rnd.randrange(len(live))
            try:
                next(live[i])
                steps += 1
            except StopIteration:
                live.pop(i)
        commits = sum(1 for e in sim.log if e[0] == "update")
        rows.append({
            "threads": nt,
            "ops": nt * 4,
            "sched_steps": steps,
            "steps_per_commit": round(steps / max(commits, 1), 2),
        })
    return rows


COLUMNS_BATCHED = ["skew", "upd_Mops", "dup_ops_in_batch", "hottest_key",
                   "hottest_leaf", "lock_serial_factor"]
COLUMNS_PROTOCOL = ["threads", "ops", "sched_steps", "steps_per_commit"]
