"""Hardware-event analogue (paper Fig. 1 / Fig. 16).

perf counters don't exist for a modeled TPU run, so we report the
machine-independent counters the paper's events proxy:
  branch instructions  -> full-key byte comparisons + suffix binary steps
  branch misses        -> suffix binary-search steps (data-dependent)
  LLC loads/misses     -> modeled 64B lines touched per op
for FB+-tree vs the binary-search baseline, uniform and zipfian.
"""
from __future__ import annotations

from typing import Dict, List

import jax.numpy as jnp
import numpy as np

from repro.core.baseline import lookup_variant
from repro.core import keys as K

from .common import build_tree, make_dataset, zipf_indices


def run(n_keys=50_000, n_ops=8_192, seed=29) -> List[Dict]:
    rows = []
    rng = np.random.default_rng(seed)
    keys, width = make_dataset("rand-int", n_keys)
    tree, ks = build_tree(keys, width)
    for dist, theta in (("uniform", 0.0), ("zipfian", 0.99)):
        idx = zipf_indices(rng, n_keys, n_ops, theta)
        qb, ql = jnp.asarray(ks.bytes[idx]), jnp.asarray(ks.lens[idx])
        for var, label in (("feature+hash", "FB+tree"), ("base", "B+tree")):
            _, _, st, ls = lookup_variant(tree, qb, ql, variant=var)
            rows.append({
                "dist": dist, "index": label,
                "key_cmp/op": round(float(st.key_compares.mean()), 2),
                "hard_branches/op": round(
                    float((st.key_compares + st.suffix_bs).mean()), 2),
                "lines/op": round(float(st.lines_touched.mean()), 1),
                "feat_rounds/op": round(float(st.feat_rounds.mean()), 2),
                "tag_cands/op": round(float(ls.tag_candidates.mean()), 2),
            })
    return rows


COLUMNS = ["dist", "index", "key_cmp/op", "hard_branches/op", "lines/op",
           "feat_rounds/op", "tag_cands/op"]
