"""Feature-size sweep (paper Fig. 13): throughput, suffix comparisons/op and
modeled LLC-lines/op as fs grows — reproduces the paper's "suffix compares
fall monotonically, lines/op is U-shaped" claim.
"""
from __future__ import annotations

from typing import Dict, List

import jax.numpy as jnp
import numpy as np

from repro.core import keys as K
from repro.core.baseline import lookup_variant
from repro.core.fbtree import TreeConfig, bulk_build

from .common import make_dataset, timed, zipf_indices


def run(datasets=("3-gram", "ycsb", "twitter", "url"), n_keys=20_000,
        n_ops=16_384, fss=(1, 2, 4, 8, 12), seed=17) -> List[Dict]:
    rows = []
    rng = np.random.default_rng(seed)
    for ds in datasets:
        keys, width = make_dataset(ds, n_keys)
        ks = K.make_keyset(keys, width)
        idx = zipf_indices(rng, len(keys), n_ops, 0.99)
        qb, ql = jnp.asarray(ks.bytes[idx]), jnp.asarray(ks.lens[idx])
        for fs in fss:
            cfg = TreeConfig.plan(max_keys=2 * n_keys, key_width=width,
                                  fs=fs)
            tree = bulk_build(cfg, ks, np.arange(n_keys, dtype=np.int32))
            def fn():
                outs = []
                for off in range(0, n_ops, 4096):
                    _, v, _, _ = lookup_variant(tree, qb[off:off + 4096],
                                                ql[off:off + 4096],
                                                variant="feature+hash")
                    outs.append(v)
                return outs
            t = timed(fn)
            _, _, st, _ = lookup_variant(tree, qb[:4096], ql[:4096],
                                         variant="feature+hash")
            rows.append({
                "dataset": ds, "fs": fs,
                "Mops": round(n_ops / t / 1e6, 3),
                "suffix_bs/op": round(float(st.suffix_bs.mean()), 3),
                "key_cmp/op": round(float(st.key_compares.mean()), 2),
                "lines/op": round(float(st.lines_touched.mean()), 1),
                "feat_rounds/op": round(float(st.feat_rounds.mean()), 2),
            })
    return rows


COLUMNS = ["dataset", "fs", "Mops", "suffix_bs/op", "key_cmp/op",
           "lines/op", "feat_rounds/op"]
