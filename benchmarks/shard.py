"""Sharded-tree benchmark (DESIGN.md §7): 1 vs 2 vs 4 shards on the three
anchor datasets.

For each dataset the suite builds one unsharded reference tree and a
``ShardedTree`` per shard count from the same keys, **parity-gates** every
configuration (lookup values/found and range-scan emissions must be
bit-identical to the reference — a routing or merge regression fails the
suite before any number is reported), then times the two serving-shaped
ops: batched point lookups (zipf-skewed) and range scans.

Run with a multi-device CPU to see real shard overlap::

    XLA_FLAGS=--xla_force_host_platform_device_count=4 \
        PYTHONPATH=src python -m benchmarks.run --suite shard

CI runs exactly that via ``--smoke`` (tiny n, parity asserts, one timing
pass, never writes the anchor). ``n_devices`` rides along in every row so
anchor rows from 1-device and 4-device hosts aren't conflated: on one
device the shard loop serializes and smaller per-shard trees are the only
win; with one device per shard the per-shard launches overlap.

Rows merge into ``BENCH_traverse.json`` under ``shard_rows``.
"""
from __future__ import annotations

from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro import shard as S
from repro.core import batch_ops as B
from repro.core import keys as K
from repro.core.fbtree import TreeConfig, bulk_build
from repro.core.traverse import TraversalEngine

from .common import make_dataset, timed, zipf_indices

SHARD_COUNTS = (1, 2, 4)


def run(datasets=("rand-int", "ycsb", "url"), n_keys=20_000, n_ops=8_192,
        n_scans=256, scan_len=64, seed=41, smoke: bool = False
        ) -> List[Dict]:
    if smoke:
        datasets = ("ycsb",)
        n_keys, n_ops, n_scans, scan_len = 600, 512, 64, 24
    n_devices = len(jax.devices())
    rows = []
    rng = np.random.default_rng(seed)
    # stats-free engine: the serving configuration (the shard layer
    # dispatches through the same engine registry as every other call site)
    eng = TraversalEngine("jnp", collect_stats=False)
    for ds in datasets:
        keys, width = make_dataset(ds, n_keys)
        ks = K.make_keyset(keys, width)
        vals = np.arange(len(keys), dtype=np.int32)
        cfg = TreeConfig.plan(max_keys=int(len(keys) * 2.5), key_width=width)
        ref = bulk_build(cfg, ks, vals)

        idx = zipf_indices(rng, len(keys), n_ops, 0.99)
        qb, ql = ks.bytes[idx], ks.lens[idx]
        sidx = rng.integers(0, len(keys), size=n_scans)
        sqb, sql = ks.bytes[sidx], ks.lens[sidx]

        v_ref, rep_ref = B.lookup_batch(ref, qb[:1024], ql[:1024],
                                        engine=eng)
        v_ref = np.asarray(v_ref)
        f_ref = np.asarray(rep_ref.found)
        k_ref, sv_ref, em_ref, _ = B.range_scan(ref, sqb, sql,
                                                max_items=scan_len,
                                                engine=eng)
        sv_ref, em_ref = np.asarray(sv_ref), np.asarray(em_ref)

        for n_shards in SHARD_COUNTS:
            st = S.sharded_build(ks, vals, n_shards, cfg=cfg)
            # ---- parity gate (before any timing)
            v_sh, rep_sh = S.lookup_batch(st, qb[:1024], ql[:1024],
                                          engine=eng)
            assert (f_ref == rep_sh.found).all(), (ds, n_shards, "found")
            assert (v_ref == v_sh).all(), (ds, n_shards, "vals")
            gk, sv_sh, em_sh, _, _ = S.range_scan(st, sqb, sql,
                                               max_items=scan_len,
                                               engine=eng)
            assert (em_ref == em_sh).all(), (ds, n_shards, "emitted")
            assert (sv_ref == sv_sh).all(), (ds, n_shards, "scan vals")

            # ---- timing
            def lookup_fn():
                return S.lookup_batch(st, qb, ql, engine=eng)[0]

            def scan_fn():
                return S.range_scan(st, sqb, sql, max_items=scan_len,
                                    engine=eng)[1]
            t_lk = timed(lookup_fn, warmup=1, iters=1 if smoke else 5)
            t_sc = timed(scan_fn, warmup=1, iters=1 if smoke else 5)
            rows.append({
                "dataset": ds, "n_keys": len(keys), "n_ops": n_ops,
                "n_shards": n_shards, "n_devices": n_devices,
                "lookup_Mops": round(n_ops / t_lk / 1e6, 3),
                "scan_Mitems": round(n_scans * scan_len / t_sc / 1e6, 3),
                "parity": "ok",
            })
    return rows


COLUMNS = ["dataset", "n_keys", "n_ops", "n_shards", "n_devices",
           "lookup_Mops", "scan_Mitems", "parity"]
