"""Benchmark driver: one module per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run [--fast] [--only ycsb,...]
      [--backend {jnp,pallas,...}] [--layout {tuple,stacked}] [--smoke]

``--suite`` is an alias for ``--only``. ``--backend``/``--layout`` apply
to the engine-selecting suites (ycsb, factor); the traverse suite always
A/Bs every backend×layout×stats combination and the scan suite A/Bs both
scan backends (jnp reference vs the fused scan kernel) on ordered and
dirtied leaves. ``--smoke`` is the CI guard: tiny trees, one timing pass,
all traversal backends (incl. the fused descent and fused scan kernels in
interpret mode) parity-checked — and ``BENCH_traverse.json`` is left
untouched so CI runs never overwrite the perf trajectory anchor.

The ``traverse`` suite writes ``BENCH_traverse.json`` at the repo root;
the ``build`` suite (host vs device ``bulk_build`` + ``rebuild``) and the
``scan`` suite (``scan_rows``) merge their rows into the same file.
Writes CSVs under out/bench/ and prints each table.
"""
from __future__ import annotations

import argparse
import csv
import json
import os
import sys
import time

from . import (contention, factor_analysis, feature_size,
               hardware_counters, memory, roofline_table, scan, shard,
               traverse_bench, ycsb)
from .common import fmt_table

SUITES = {
    "ycsb": ("Fig.11/17 — YCSB core workloads",
             lambda fast, **eng: ycsb.run(n_keys=8_000 if fast else 20_000,
                                          n_ops=8_192 if fast else 40_960,
                                          **eng),
             ycsb.COLUMNS),
    "factor": ("Fig.12a — structural factor analysis",
               lambda fast, **eng: factor_analysis.run(
                   n_keys=8_000 if fast else 20_000,
                   n_ops=8_192 if fast else 16_384, **eng),
               factor_analysis.COLUMNS),
    "traverse": ("Engine A/B — traversal backends × layouts × stats",
                 lambda fast, **kw: traverse_bench.run(
                     n_keys=8_000 if fast else 20_000,
                     n_ops=8_192 if fast else 16_384, **kw),
                 traverse_bench.COLUMNS),
    "build": ("DESIGN.md §5 — host vs device bulk build + rebuild",
              lambda fast: traverse_bench.run_build(
                  sizes=(2_000, 8_000) if fast else (5_000, 20_000)),
              traverse_bench.BUILD_COLUMNS),
    "memory": ("Fig.12b — index memory consumption",
               lambda fast: memory.run(n_keys=8_000 if fast else 20_000),
               memory.COLUMNS),
    "feature_size": ("Fig.13 — feature-size sweep",
                     lambda fast: feature_size.run(
                         n_keys=8_000 if fast else 20_000,
                         n_ops=4_096 if fast else 16_384,
                         fss=(1, 2, 4) if fast else (1, 2, 4, 8, 12)),
                     feature_size.COLUMNS),
    "contention": ("Fig.14/15 — update scalability under contention",
                   lambda fast: contention.run_batched(
                       n_keys=8_000 if fast else 20_000,
                       n_ops=8_192 if fast else 32_768),
                   contention.COLUMNS_BATCHED),
    "contention_protocol": ("Fig.14 (protocol view) — retries vs threads",
                            lambda fast: contention.run_protocol(),
                            contention.COLUMNS_PROTOCOL),
    "hardware": ("Fig.1/16 — hardware-event analogue counters",
                 lambda fast: hardware_counters.run(
                     n_keys=10_000 if fast else 50_000),
                 hardware_counters.COLUMNS),
    "scan": ("Fig.17(E) — range scan engine A/B (jnp vs fused × "
             "ordered/dirty)",
             lambda fast, **kw: scan.run(n_keys=8_000 if fast else 20_000,
                                         **kw),
             scan.COLUMNS),
    "shard": ("DESIGN.md §7 — sharded tree: 1 vs 2 vs 4 shards, "
              "parity-gated",
              lambda fast, **kw: shard.run(n_keys=8_000 if fast else 20_000,
                                           n_ops=4_096 if fast else 8_192,
                                           **kw),
              shard.COLUMNS),
    "roofline": ("§Roofline — dry-run derived table",
                 lambda fast: roofline_table.run(),
                 roofline_table.COLUMNS),
}


# suites that accept traversal-engine selection
_ENGINE_SUITES = ("ycsb", "factor")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--only", default=None)
    ap.add_argument("--suite", default=None, help="alias for --only")
    ap.add_argument("--smoke", action="store_true",
                    help="CI smoke: tiny n, parity asserts across all "
                         "backends; skips the BENCH_traverse.json write")
    ap.add_argument("--out", default="out/bench")
    ap.add_argument("--backend", default="jnp",
                    help="traversal branch backend (jnp, pallas, ...)")
    ap.add_argument("--layout", default=None, choices=(None, "tuple",
                                                       "stacked"),
                    help="descent layout (default: tree config)")
    args = ap.parse_args(argv)
    only = args.suite or args.only
    names = only.split(",") if only else list(SUITES)
    os.makedirs(args.out, exist_ok=True)
    failed = []
    report = []     # per-suite timing/status -> out/bench_report.json
    for name in names:
        title, fn, cols = SUITES[name]
        eng = (dict(backend=args.backend, layout=args.layout)
               if name in _ENGINE_SUITES else {})
        if args.smoke and name in ("traverse", "scan", "shard"):
            eng["smoke"] = True
        t0 = time.time()
        try:
            rows = fn(args.fast, **eng)
        except Exception as e:  # keep the suite running
            print(f"\n== {name}: FAILED — {type(e).__name__}: {e}",
                  flush=True)
            import traceback
            traceback.print_exc()
            failed.append(name)
            report.append({"suite": name, "case": title,
                           "wall_s": round(time.time() - t0, 3),
                           "status": "failed", "rows": 0})
            continue
        dt = time.time() - t0
        # the parity-gated suites assert inside fn(), so reaching here
        # means their backend A/B checks passed
        report.append({"suite": name, "case": title,
                       "wall_s": round(dt, 3), "status": "ok",
                       "rows": len(rows)})
        print(f"\n== {title}  [{name}, {dt:.1f}s]")
        print(fmt_table(rows, cols))
        with open(os.path.join(args.out, f"{name}.csv"), "w",
                  newline="") as f:
            w = csv.DictWriter(f, fieldnames=cols, extrasaction="ignore")
            w.writeheader()
            w.writerows(rows)
        if args.smoke:
            continue  # never clobber the perf trajectory anchor from CI
        if name == "traverse":
            print("engine A/B written to", traverse_bench.write_json(rows))
        elif name == "build":
            print("build rows written to",
                  traverse_bench.write_json(build_rows=rows))
        elif name == "scan":
            print("scan rows written to",
                  traverse_bench.write_json(scan_rows=rows))
        elif name == "shard":
            print("shard rows written to",
                  traverse_bench.write_json(shard_rows=rows))
    rpt_path = os.path.join(args.out, "..", "bench_report.json")
    rpt_path = os.path.normpath(rpt_path)
    with open(rpt_path, "w") as f:
        json.dump(report, f, indent=2)
        f.write("\n")
    print("\nCSV written to", args.out)
    print("suite report written to", rpt_path)
    if failed:
        raise SystemExit(f"suites failed: {', '.join(failed)}")


if __name__ == "__main__":
    main()
