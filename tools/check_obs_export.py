#!/usr/bin/env python
"""Validate telemetry JSON-lines exports against the event schema
(DESIGN.md §9) — and, with ``--run-serving-smoke``, produce one to
validate by driving the sharded serving example with telemetry on.

Validation mode (the CI gate for any ``*.events.jsonl`` artifact, e.g. a
failing chaos schedule's dump):

  PYTHONPATH=src python tools/check_obs_export.py out/chaos/*.events.jsonl

Every line must parse as JSON and pass ``repro.obs.validate_event`` — the
validator imports the same ``EVENT_TYPES`` table the emitter enforces, so
an export that validates here is exactly one the emitter could have
produced; unknown or malformed event types fail the check.

Serving smoke (the CI telemetry step):

  JAX_PLATFORMS=cpu PYTHONPATH=src python tools/check_obs_export.py \
      --run-serving-smoke --out out/obs

Runs a tiny sharded serving engine (2-shard prefix-cache tree) under an
injected dispatch fault with telemetry enabled, then asserts the full
pipeline end to end: non-empty request-latency histogram (p50/p99),
shard retry + degraded counters from the fault, at least one successful
``publish`` event from a ``compact`` barrier, and a schema-clean
JSON-lines export.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), os.pardir, "src"))

from repro import obs


def validate_file(path: str) -> int:
    """Schema-check one JSON-lines export; returns the number of
    violations (each printed with its line number)."""
    bad = 0
    n = 0
    with open(path, encoding="utf-8") as f:
        for lineno, line in enumerate(f, 1):
            line = line.strip()
            if not line:
                continue
            n += 1
            try:
                e = json.loads(line)
            except json.JSONDecodeError as exc:
                print(f"{path}:{lineno}: malformed JSON: {exc}")
                bad += 1
                continue
            for v in obs.validate_event(e):
                print(f"{path}:{lineno}: {v}")
                bad += 1
    status = "OK" if not bad else f"{bad} violations"
    print(f"{path}: {n} events, {status}")
    return bad


def run_serving_smoke(out_dir: str) -> int:
    """Drive the sharded serving engine with telemetry on; returns 0 when
    every acceptance assertion and the export schema check pass."""
    import jax
    import numpy as np

    from repro.configs import get_config
    from repro.core.faults import FaultPlan, FaultSpec, RetryPolicy
    from repro.models import lm
    from repro.serving.engine import Engine, ServeConfig

    obs.enable()
    obs.reset()

    # two dispatch faults on the prefix-cache tree: a transient drop the
    # retry loop absorbs (shard 1), and a window long enough to exhaust
    # all three retry attempts (shard 0) so one lookup degrades to the
    # barrier snapshot
    plan = FaultPlan((
        FaultSpec("shard.dispatch.lookup", "drop_shard", shard=1,
                  nth=0, count=1),
        FaultSpec("shard.dispatch.lookup", "drop_shard", shard=0,
                  nth=1, count=3),
    ), sleep=lambda s: None)
    cfg = get_config("yi-9b", smoke=True)
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    scfg = ServeConfig(max_batch=2, s_max=96, block_tokens=8, n_pages=128,
                       max_new_tokens=4, tree_shards=2, faults=plan)
    eng = Engine(cfg, params, scfg)
    eng.prefix.retry = RetryPolicy(max_attempts=3, sleep=lambda s: None)

    rng = np.random.default_rng(0)
    shared = rng.integers(0, cfg.vocab, size=32).astype(np.int32)
    reqs = [np.concatenate([shared, rng.integers(0, cfg.vocab, 8)])
            .astype(np.int32) for _ in range(6)]
    done = eng.run(reqs)
    plan.disarm()
    rep = eng.prefix.compact()           # publish barrier, label="compact"

    print(obs.console_summary())
    path = os.path.join(out_dir, "serving_smoke.events.jsonl")
    n_ev = obs.export_events_jsonl(path)
    prom = os.path.join(out_dir, "serving_smoke.prom")
    os.makedirs(out_dir, exist_ok=True)
    with open(prom, "w") as f:
        f.write(obs.prometheus_text())

    failures = []

    def check(ok: bool, what: str):
        print(("PASS" if ok else "FAIL"), what)
        if not ok:
            failures.append(what)

    check(all(r.done for r in done), "all requests completed")
    h = obs.get_metric("serve.request_latency_s")
    check(h is not None and h.count >= len(reqs),
          "request-latency histogram is populated")
    if h is not None and h.count:
        check(h.p50 > 0 and h.p99 >= h.p50,
              f"latency quantiles sane (p50={h.p50:.4g}s p99={h.p99:.4g}s)")
    retries = obs.get_metric("shard.retries", op="lookup")
    check(retries is not None and retries.value > 0,
          "shard retry counter fired under injected fault")
    degraded = obs.get_metric("shard.degraded_lanes", op="lookup")
    check(degraded is not None and degraded.value > 0,
          "degraded-lane counter fired under injected fault")
    check(rep.ok, f"compact publish succeeded (reason={rep.reason!r})")
    pubs = [e for e in obs.events()
            if e["type"] == "publish" and e["ok"]
            and e["label"] == "compact"]
    check(len(pubs) >= 1, "publish event recorded from the compact barrier")
    check(n_ev > 0, f"event export is non-empty ({n_ev} events)")
    check(validate_file(path) == 0, "export passes the schema check")

    if failures:
        print(f"serving smoke: {len(failures)} check(s) failed")
        return 1
    print(f"serving smoke: all checks passed; artifacts in {out_dir}")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("files", nargs="*",
                    help="JSON-lines event exports to validate")
    ap.add_argument("--run-serving-smoke", action="store_true",
                    help="drive the sharded serving example with telemetry "
                         "enabled and validate its export end to end")
    ap.add_argument("--out", default="out/obs",
                    help="artifact directory for --run-serving-smoke")
    args = ap.parse_args(argv)
    if not args.files and not args.run_serving_smoke:
        ap.error("nothing to do: pass export files and/or "
                 "--run-serving-smoke")
    rc = 0
    if args.run_serving_smoke:
        rc |= run_serving_smoke(args.out)
    bad = 0
    for path in args.files:
        bad += validate_file(path)
    return 1 if (rc or bad) else 0


if __name__ == "__main__":
    sys.exit(main())
