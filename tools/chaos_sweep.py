#!/usr/bin/env python
"""Seeded chaos sweep over the tree lifecycle + fault layer (DESIGN.md §8).

Each schedule builds a fresh tree (or serving cache), runs one scenario
under a seeded random :class:`repro.core.faults.FaultPlan`, checks
``core.fsck`` after every step, then heals/disarms, runs the recovery
barrier, and verifies that every *committed* op survived — nothing lost,
nothing phantom. A schedule fails loudly (AssertionError) on any invariant
break, so the sweep doubles as the CI chaos smoke.

Scenarios (× shard counts):

  rebuild    single-tree lifecycle rebuild under abort/corrupt faults
  rebalance  sharded rebalance barrier under abort/corrupt faults
  compact    PrefixCache.compact (serving layer) under abort/corrupt faults
  lookup     routed lookup/update/insert/remove under drop/delay faults

Determinism: the fault schedule is a pure function of (seed, n_shards,
scenario) — replay a failing schedule with the same triple.

Usage (CI smoke):

  JAX_PLATFORMS=cpu PYTHONPATH=src python tools/chaos_sweep.py \
      --schedules 200 --shards 1,4
"""
from __future__ import annotations

import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), os.pardir, "src"))

import numpy as np

from repro import obs
from repro.core import batch_ops as B
from repro.core import fsck
from repro.core import keys as K
from repro.core.faults import FaultPlan, RetryPolicy
from repro.core.fbtree import TreeConfig, bulk_build
from repro.core.lifecycle import TreeVersionManager
from repro import shard as SH

W = 8            # key width (uint64 big-endian)
N0 = 96          # live keys per schedule (fixed -> jit cache reuse)
BATCH = 16       # routed-op lane count (fixed -> jit cache reuse)
MAX_KEYS = 512
SCENARIOS = ("rebuild", "rebalance", "compact", "lookup")
# no real sleeping in the sweep: retries and delays are logical only
FAST = RetryPolicy(max_attempts=2, sleep=lambda s: None)
P = {"abort": 0.35, "corrupt": 0.25, "drop_shard": 0.30, "delay": 0.15}

_CFG_CACHE = {}


def _cfg() -> TreeConfig:
    """One shared TreeConfig for every schedule: pool shapes are cap-sized,
    so a single config means a single device-build compilation."""
    if "cfg" not in _CFG_CACHE:
        _CFG_CACHE["cfg"] = TreeConfig.plan(max_keys=MAX_KEYS, key_width=W)
    return _CFG_CACHE["cfg"]


def _keyset(ints) -> K.KeySet:
    return K.make_keyset([int(x).to_bytes(W, "big") for x in ints], W)


def _fresh_ints(rng, model, n):
    out = []
    while len(out) < n:
        x = int(rng.integers(0, 1 << 40))
        if x not in model and x not in out:
            out.append(x)
    return out


def _verify(obj, model, sharded: bool, ctx: str):
    """Every committed key must be found with its committed value.

    Batches are padded to a multiple of 64 (repeating the first key) so
    the sweep reuses a handful of compiled lookup shapes.
    """
    ints = sorted(model)
    if not ints:
        return
    pad = (-len(ints)) % 64
    q = ints + [ints[0]] * pad
    ks = _keyset(q)
    if sharded:
        v, rep = SH.lookup_batch(obj, ks.bytes, ks.lens)
    else:
        v, rep = B.lookup_batch(obj, ks.bytes, ks.lens)
    found = np.asarray(rep.found)
    vv = np.asarray(v)
    exp = np.array([model[i] for i in q])
    assert found.all(), f"{ctx}: committed key missing"
    assert (vv == exp).all(), f"{ctx}: committed value lost"


def _fsck_ok(obj, ctx: str):
    r = fsck.check(obj)
    assert r.ok, f"{ctx}: fsck violations {r.violations[:3]}"


# ------------------------------------------------------------- scenarios

def _scenario_rebuild(n_shards, plan, rng, model):
    """Lifecycle rebuild publishes under abort/corrupt; the serving version
    must stay fsck-clean and bit-stable through every failed attempt."""
    ints = sorted(model)
    tree = bulk_build(_cfg(), _keyset(ints),
                      np.array([model[i] for i in ints], np.int32))
    plan.disarm()
    # churn fault-free: tombstones give the rebuild something to reclaim
    rm = [int(x) for x in rng.choice(ints, BATCH, replace=False)]
    q = _keyset(rm)
    tree, _ = B.remove_batch(tree, q.bytes, q.lens)
    for k in rm:
        del model[k]
    new = _fresh_ints(rng, model, BATCH)
    nv = rng.integers(0, 1 << 30, BATCH).astype(np.int32)
    q = _keyset(new)
    tree, _, _ = B.insert_batch(tree, q.bytes, q.lens, nv)
    model.update(zip(new, (int(x) for x in nv)))

    mgr = TreeVersionManager(tree, faults=plan)
    plan.arm()
    for _ in range(4):
        v0 = mgr.version
        rep = mgr.rebuild()
        plan.disarm()
        _fsck_ok(mgr.current, "rebuild attempt")
        _verify(mgr.current, model, False, "rebuild attempt")
        if not rep.ok:
            assert mgr.version == v0, "failed publish advanced the version"
        plan.arm()
        if rep.ok:
            break
    plan.disarm()
    rep = mgr.rebuild()
    assert rep.ok, f"fault-free rebuild failed: {rep.reason}"
    _fsck_ok(mgr.current, "post-recovery")
    _verify(mgr.current, model, False, "post-recovery")


def _scenario_rebalance(n_shards, plan, rng, model):
    """Sharded rebalance barrier under abort/corrupt faults."""
    ints = sorted(model)
    st = SH.sharded_build(_keyset(ints),
                          np.array([model[i] for i in ints], np.int32),
                          n_shards, cfg=_cfg())
    plan.disarm()
    rm = [int(x) for x in rng.choice(ints, BATCH, replace=False)]
    q = _keyset(rm)
    st, _ = SH.remove_batch(st, q.bytes, q.lens)
    for k in rm:
        del model[k]
    new = _fresh_ints(rng, model, BATCH)
    nv = rng.integers(0, 1 << 30, BATCH).astype(np.int32)
    q = _keyset(new)
    st, _, _ = SH.insert_batch(st, q.bytes, q.lens, nv)
    model.update(zip(new, (int(x) for x in nv)))

    mgr = TreeVersionManager(st, faults=plan)
    plan.arm()
    for _ in range(4):
        v0 = mgr.version
        rep = mgr.rebalance()
        plan.disarm()
        _fsck_ok(mgr.current, "rebalance attempt")
        _verify(mgr.current, model, True, "rebalance attempt")
        if not rep.ok:
            assert mgr.version == v0, "failed publish advanced the version"
        plan.arm()
        if rep.ok:
            break
    plan.disarm()
    plan.heal()
    rep = mgr.rebalance()
    assert rep.ok, f"fault-free rebalance failed: {rep.reason}"
    _fsck_ok(mgr.current, "post-recovery")
    _verify(mgr.current, model, True, "post-recovery")


def _scenario_compact(n_shards, plan, rng, model):
    """PrefixCache.compact is an atomic publish: a failed compaction must
    leave the cache serving exactly what it served before."""
    from repro.serving.prefix_cache import PrefixCache
    plan.disarm()
    pc = PrefixCache(n_pages=64, block_tokens=8, max_keys=2048,
                     n_shards=n_shards, faults=plan, retry=FAST)
    prompts = [rng.integers(0, 1000, size=24).astype(np.int32)
               for _ in range(6)]
    for p in prompts:
        hb, _pages = pc.match([p])
        pc.publish(p, hb[0])
    ref_hits, ref_pages = pc.match(prompts)
    plan.arm()
    for _ in range(3):
        rep = pc.compact()
        plan.disarm()
        _fsck_ok(pc.tree, "compact attempt")
        hits, pages = pc.match(prompts)
        assert hits == ref_hits and pages == ref_pages, \
            "compact changed serving results"
        plan.arm()
        if rep.ok:
            break
    plan.disarm()
    plan.heal()
    rep = pc.compact()
    assert rep.ok, f"fault-free compact failed: {rep.reason}"
    _fsck_ok(pc.tree, "post-recovery")
    hits, pages = pc.match(prompts)
    assert hits == ref_hits and pages == ref_pages, \
        "recovery compact changed serving results"


def _scenario_lookup(n_shards, plan, rng, model):
    """Routed ops under sticky drops + delays: failed lanes are never
    committed, degraded lanes serve the last-barrier snapshot, and the
    recovery rebalance loses nothing."""
    ints = sorted(model)
    st = SH.sharded_build(_keyset(ints),
                          np.array([model[i] for i in ints], np.int32),
                          n_shards, cfg=_cfg())
    snap_model = dict(model)      # snapshots advance only at barriers
    plan.arm()
    for _ in range(3):
        op = ("lookup", "update", "remove", "insert")[int(rng.integers(4))]
        if op == "insert":
            keys = _fresh_ints(rng, model, BATCH)
            nv = rng.integers(0, 1 << 30, BATCH).astype(np.int32)
            q = _keyset(keys)
            st, rep, _ = SH.insert_batch(st, q.bytes, q.lens, nv,
                                         faults=plan, retry=FAST)
            failed = np.asarray(rep.failed)
            for i, k in enumerate(keys):
                if not failed[i]:
                    model[k] = int(nv[i])
        elif op == "update":
            keys = [int(x) for x in
                    rng.choice(sorted(model), BATCH, replace=False)]
            nv = rng.integers(0, 1 << 30, BATCH).astype(np.int32)
            q = _keyset(keys)
            st, rep = SH.update_batch(st, q.bytes, q.lens, nv,
                                      faults=plan, retry=FAST)
            failed = np.asarray(rep.failed)
            for i, k in enumerate(keys):
                if not failed[i]:
                    model[k] = int(nv[i])
        elif op == "remove":
            keys = [int(x) for x in
                    rng.choice(sorted(model), BATCH, replace=False)]
            q = _keyset(keys)
            st, rep = SH.remove_batch(st, q.bytes, q.lens,
                                      faults=plan, retry=FAST)
            failed = np.asarray(rep.failed)
            for i, k in enumerate(keys):
                if not failed[i]:
                    del model[k]
        else:
            keys = [int(x) for x in
                    rng.choice(sorted(model), BATCH, replace=False)]
            q = _keyset(keys)
            v, rep = SH.lookup_batch(st, q.bytes, q.lens,
                                     faults=plan, retry=FAST)
            deg = np.asarray(rep.degraded)
            found = np.asarray(rep.found)
            vv = np.asarray(v)
            for i, k in enumerate(keys):
                ref = snap_model if deg[i] else model
                assert found[i] == (k in ref), \
                    f"lookup: lane {i} found={found[i]} degraded={deg[i]}"
                if k in ref:
                    assert int(vv[i]) == ref[k], \
                        f"lookup: lane {i} wrong value (degraded={deg[i]})"
        _fsck_ok(st, f"after routed {op}")
    plan.heal()
    plan.disarm()
    st.health.reset()
    st, _rep = SH.rebalance(st)
    _fsck_ok(st, "post-recovery")
    _verify(st, model, True, "post-recovery")
    # removed keys must stay gone after recovery
    gone = sorted(set(snap_model) - set(model))[:BATCH]
    if gone:
        gone = gone + [gone[0]] * (BATCH - len(gone))
        q = _keyset(gone)
        _v, rep = SH.lookup_batch(st, q.bytes, q.lens)
        assert not np.asarray(rep.found).any(), \
            "removed key resurrected by recovery"


_SCENARIO_FNS = {"rebuild": _scenario_rebuild,
                 "rebalance": _scenario_rebalance,
                 "compact": _scenario_compact,
                 "lookup": _scenario_lookup}


def run_schedule(seed: int, n_shards: int, scenario: str) -> dict:
    """Run one seeded chaos schedule; raises on any invariant violation.

    Returns ``{"seed", "n_shards", "scenario", "events"}`` where ``events``
    is the number of faults that actually fired (replayable from the seed).
    """
    sidx = SCENARIOS.index(scenario)
    rng = np.random.default_rng([seed, n_shards, sidx])
    plan = FaultPlan(seed=(seed << 8) ^ (n_shards << 4) ^ sidx, p=P,
                     sleep=lambda s: None)
    base = np.sort(rng.choice(1 << 40, N0, replace=False))
    vals = rng.integers(0, 1 << 30, N0).astype(np.int32)
    model = {int(k): int(v) for k, v in zip(base, vals)}
    _SCENARIO_FNS[scenario](n_shards, plan, rng, model)
    return {"seed": seed, "n_shards": n_shards, "scenario": scenario,
            "events": len(plan.events)}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--schedules", type=int, default=40,
                    help="total schedules to run (CI uses 200)")
    ap.add_argument("--shards", default="1,4",
                    help="comma-separated shard counts")
    ap.add_argument("--scenarios", default=",".join(SCENARIOS))
    ap.add_argument("--events-dir", default="out/chaos",
                    help="where failing schedules dump their telemetry "
                         "event logs (JSON lines, one file per failure)")
    args = ap.parse_args(argv)
    shard_list = [int(s) for s in args.shards.split(",")]
    scen = [s for s in args.scenarios.split(",") if s]
    for s in scen:
        if s not in SCENARIOS:
            ap.error(f"unknown scenario {s!r}; one of {SCENARIOS}")

    # telemetry on for the whole sweep: each schedule's event log is the
    # replay context a failure ships as its CI artifact
    obs.enable()
    t0 = time.time()
    events = 0
    fails = []
    for i in range(args.schedules):
        sc = scen[i % len(scen)]
        nsh = shard_list[(i // len(scen)) % len(shard_list)]
        obs.reset()                     # one event log per schedule
        try:
            r = run_schedule(i, nsh, sc)
            events += r["events"]
        except Exception as e:  # noqa: BLE001 — report, keep sweeping
            fails.append((i, nsh, sc, repr(e)))
            dump = os.path.join(args.events_dir,
                                f"fail_seed{i}_shards{nsh}_{sc}.events.jsonl")
            n_ev = obs.export_events_jsonl(dump)
            summary = ", ".join(f"{k}={v}" for k, v in
                                obs.event_summary().items()) or "none"
            print(f"FAIL seed={i} shards={nsh} scenario={sc}: {e!r}\n"
                  f"     events: {summary}\n"
                  f"     log: {dump} ({n_ev} events)")
    dt = time.time() - t0
    print(f"chaos sweep: {args.schedules} schedules, {events} faults fired, "
          f"{len(fails)} failures, {dt:.1f}s")
    if not fails and events == 0:
        print("ERROR: no faults fired — the sweep proved nothing")
        return 2
    return 1 if fails else 0


if __name__ == "__main__":
    sys.exit(main())
