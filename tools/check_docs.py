"""Doc-drift guard (CI): DESIGN.md anchors cited from code must exist.

Code and docstrings cite design sections as ``DESIGN.md §N``; this script
collects every such citation under src/, benchmarks/, tests/, tools/ and
examples/ and fails if a cited section has no matching ``## §N`` heading in
DESIGN.md — the cheap tripwire against renumbering or deleting a section
while stale references linger. Also asserts the entry-point docs exist and
that README.md still shows the tier-1 verify command.

Usage: python tools/check_docs.py   (exit 0 = clean, 1 = drift, with a list)
"""
from __future__ import annotations

import os
import re
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SCAN_DIRS = ("src", "benchmarks", "tests", "tools", "examples")
CITE_RE = re.compile(r"DESIGN\.md\s+§(\d+)")
ANCHOR_RE = re.compile(r"^##\s+§(\d+)\s+(.*)$", re.MULTILINE)
TIER1 = "python -m pytest -x -q"
# sections that must exist under these exact titles: subsystems whose
# docs are part of their acceptance criteria
REQUIRED_SECTIONS = {9: "Observability"}


def design_sections(design_path: str) -> dict:
    """``{section number: title}`` for every ``## §N Title`` anchor."""
    with open(design_path, encoding="utf-8") as f:
        return {int(n): t.strip() for n, t in ANCHOR_RE.findall(f.read())}


def cited_sections(root: str):
    """Yield (relpath, lineno, section) for every DESIGN.md §N citation."""
    for d in SCAN_DIRS:
        for dirpath, _, files in os.walk(os.path.join(root, d)):
            for fn in files:
                if not fn.endswith((".py", ".md")):
                    continue
                path = os.path.join(dirpath, fn)
                with open(path, encoding="utf-8", errors="replace") as f:
                    for i, line in enumerate(f, 1):
                        for m in CITE_RE.finditer(line):
                            yield (os.path.relpath(path, root), i,
                                   int(m.group(1)))


def main() -> int:
    errors = []
    design = os.path.join(ROOT, "DESIGN.md")
    readme = os.path.join(ROOT, "README.md")
    for path in (design, readme):
        if not os.path.exists(path):
            errors.append(f"missing entry-point doc: {os.path.basename(path)}")
    if not errors:
        sections = design_sections(design)
        if not sections:
            errors.append("DESIGN.md has no '## §N' section anchors")
        for num, title in REQUIRED_SECTIONS.items():
            got = sections.get(num)
            if got is None:
                errors.append(f"DESIGN.md is missing required section "
                              f"§{num} {title!r}")
            elif title.lower() not in got.lower():
                errors.append(f"DESIGN.md §{num} is titled {got!r}, "
                              f"expected it to cover {title!r}")
        n_cites = 0
        for rel, lineno, sec in cited_sections(ROOT):
            n_cites += 1
            if sec not in sections:
                errors.append(
                    f"{rel}:{lineno}: cites DESIGN.md §{sec}, but DESIGN.md "
                    f"only defines {sorted(sections)}")
        with open(readme, encoding="utf-8") as f:
            if TIER1 not in f.read():
                errors.append(
                    f"README.md no longer shows the tier-1 command ({TIER1})")
    if errors:
        print("doc-drift check FAILED:")
        for e in errors:
            print("  -", e)
        return 1
    print(f"doc-drift check OK ({n_cites} DESIGN.md citations, "
          f"sections {sorted(sections)})")
    return 0


if __name__ == "__main__":
    sys.exit(main())
